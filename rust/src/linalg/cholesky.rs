//! Cholesky factorization (the heart of the paper's CQ scheme, Eq. (7)).

use super::matrix::Matrix;
use std::fmt;

#[derive(Debug)]
pub enum CholeskyError {
    NotSquare(usize, usize),
    NotPd { index: usize, pivot: f32 },
    NonFinite,
}

impl fmt::Display for CholeskyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CholeskyError::NotSquare(r, c) => write!(f, "matrix is not square ({r}x{c})"),
            CholeskyError::NotPd { index, pivot } => {
                write!(f, "matrix is not positive definite (pivot {pivot} at index {index})")
            }
            CholeskyError::NonFinite => {
                write!(f, "non-finite entry encountered during factorization")
            }
        }
    }
}

impl std::error::Error for CholeskyError {}

/// Lower-triangular Cholesky factor `C` with `C·Cᵀ = A`.
///
/// Standard `LLᵀ` (Cholesky–Banachiewicz) with f64 accumulation of the
/// pivot sums for stability at f32 storage precision. The strict upper
/// triangle of the result is zero.
pub fn cholesky(a: &Matrix) -> Result<Matrix, CholeskyError> {
    if !a.is_square() {
        return Err(CholeskyError::NotSquare(a.rows(), a.cols()));
    }
    let n = a.rows();
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            // dot of rows i and j of L over [0, j)
            let mut s = 0.0f64;
            {
                let li = l.row(i);
                let lj = l.row(j);
                for k in 0..j {
                    s += li[k] as f64 * lj[k] as f64;
                }
            }
            if i == j {
                let pivot = a[(i, i)] as f64 - s;
                if !pivot.is_finite() {
                    return Err(CholeskyError::NonFinite);
                }
                if pivot <= 0.0 {
                    return Err(CholeskyError::NotPd { index: i, pivot: pivot as f32 });
                }
                l[(i, j)] = pivot.sqrt() as f32;
            } else {
                let denom = l[(j, j)] as f64;
                let v = ((a[(i, j)] as f64 - s) / denom) as f32;
                if !v.is_finite() {
                    return Err(CholeskyError::NonFinite);
                }
                l[(i, j)] = v;
            }
        }
    }
    Ok(l)
}

/// Cholesky with escalating diagonal jitter, mirroring the paper's `+εI`
/// regularization (Eq. (7)): retries with ε · 10^t for t = 0.. until the
/// factorization succeeds. Returns the factor and the jitter actually used.
pub fn cholesky_jittered(
    a: &Matrix,
    eps: f32,
    max_tries: u32,
) -> Result<(Matrix, f32), CholeskyError> {
    let mut jitter = eps;
    let mut last_err = None;
    for _ in 0..max_tries {
        let mut reg = a.clone();
        reg.add_diag(jitter);
        match cholesky(&reg) {
            Ok(l) => return Ok((l, jitter)),
            Err(e) => {
                last_err = Some(e);
                jitter *= 10.0;
            }
        }
    }
    Err(last_err.unwrap_or(CholeskyError::NonFinite))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::{matmul_nt, syrk};
    use crate::util::rng::Rng;

    #[test]
    fn factor_known_matrix() {
        // Paper's Appendix C.1 toy matrix [[10,3],[3,1]] + tiny eps is PD.
        let a = Matrix::from_rows(&[&[10.0, 3.0], &[3.0, 1.0 + 1e-3]]);
        let l = cholesky(&a).unwrap();
        let recon = matmul_nt(&l, &l);
        assert!(recon.max_abs_diff(&a) < 1e-5);
        assert_eq!(l[(0, 1)], 0.0, "upper triangle zero");
    }

    #[test]
    fn factor_random_spd() {
        let mut rng = Rng::new(1);
        for n in [1, 2, 5, 16, 48] {
            let g = Matrix::randn(n, n + 4, 1.0, &mut rng);
            let mut a = syrk(&g);
            a.add_diag(0.1);
            let l = cholesky(&a).unwrap();
            let recon = matmul_nt(&l, &l);
            assert!(recon.max_abs_diff(&a) < 1e-3 * n as f32, "n={n}");
        }
    }

    #[test]
    fn rejects_indefinite() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigenvalues 3, -1
        assert!(matches!(cholesky(&a), Err(CholeskyError::NotPd { .. })));
    }

    #[test]
    fn rejects_non_square() {
        let a = Matrix::zeros(2, 3);
        assert!(matches!(cholesky(&a), Err(CholeskyError::NotSquare(2, 3))));
    }

    #[test]
    fn jitter_rescues_psd() {
        // Singular PSD matrix: rank-1.
        let a = Matrix::from_rows(&[&[1.0, 1.0], &[1.0, 1.0]]);
        assert!(cholesky(&a).is_err());
        let (l, jitter) = cholesky_jittered(&a, 1e-6, 12).unwrap();
        assert!(jitter >= 1e-6);
        assert!(!l.has_non_finite());
    }

    #[test]
    fn rejects_nan() {
        let mut a = Matrix::eye(3);
        a[(2, 2)] = f32::NAN;
        assert!(cholesky(&a).is_err());
    }
}

//! Conversions between our [`Matrix`]/vec types and `xla::Literal`.

use crate::linalg::Matrix;
use crate::runtime::xla;
use crate::util::error::Result;

/// Row-major f32 matrix → 2-D literal.
pub fn matrix_to_literal(m: &Matrix) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(m.data()).reshape(&[m.rows() as i64, m.cols() as i64])?)
}

/// f32 slice → literal with the given shape.
pub fn vec_f32_to_literal(v: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    let n: usize = shape.iter().product();
    crate::ensure!(v.len() == n, "shape {:?} needs {} elems, got {}", shape, n, v.len());
    Ok(xla::Literal::vec1(v).reshape(&dims)?)
}

/// i32 slice → literal with the given shape (labels / token ids).
pub fn vec_i32_to_literal(v: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    let n: usize = shape.iter().product();
    crate::ensure!(v.len() == n, "shape {:?} needs {} elems, got {}", shape, n, v.len());
    Ok(xla::Literal::vec1(v).reshape(&dims)?)
}

/// Scalar f32 literal.
pub fn scalar_f32(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// 2-D literal → Matrix.
pub fn literal_to_matrix(l: &xla::Literal, rows: usize, cols: usize) -> Result<Matrix> {
    let v = l.to_vec::<f32>()?;
    crate::ensure!(v.len() == rows * cols, "literal has {} elems, want {}x{}", v.len(), rows, cols);
    Ok(Matrix::from_vec(rows, cols, v))
}

/// Any-rank f32 literal → flat vec.
pub fn literal_to_vec_f32(l: &xla::Literal) -> Result<Vec<f32>> {
    Ok(l.to_vec::<f32>()?)
}

/// Scalar f32 from a literal (loss outputs).
pub fn literal_to_scalar_f32(l: &xla::Literal) -> Result<f32> {
    let v = l.to_vec::<f32>()?;
    crate::ensure!(v.len() == 1, "expected scalar, got {} elems", v.len());
    Ok(v[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_roundtrip() {
        let m = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let l = matrix_to_literal(&m).unwrap();
        let back = literal_to_matrix(&l, 2, 2).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn i32_literal_shape() {
        let l = vec_i32_to_literal(&[1, 2, 3, 4, 5, 6], &[2, 3]).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(vec_f32_to_literal(&[1.0, 2.0], &[3]).is_err());
    }
}

//! PJRT runtime — loads the AOT artifacts produced by `python/compile/aot.py`
//! and executes them on the CPU PJRT client from the L3 hot path.
//!
//! Python never runs here: the interchange is `artifacts/*.hlo.txt` (HLO
//! text; see DESIGN.md §1 for why text, not serialized protos) plus
//! `artifacts/manifest.json` describing shapes and parameter inventories.
//!
//! `PjRtClient` is `Rc`-backed (not `Send`), so a [`Runtime`] is
//! thread-local by construction; the coordinator gives each worker its own.

pub mod client;
pub mod literal;
pub mod manifest;
pub mod xla;

pub use client::Runtime;
pub use literal::{literal_to_matrix, literal_to_vec_f32, matrix_to_literal};
pub use manifest::{ArtifactSpec, Manifest, ModelInfo, ParamInfo, TensorSpec};

//! The PJRT execution engine: compile-once, execute-many.

use super::manifest::{ArtifactSpec, Manifest};
use super::xla;
use crate::util::error::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// A CPU PJRT client with a per-artifact executable cache.
///
/// Not `Send`: one `Runtime` per thread (the coordinator arranges this).
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: std::cell::RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Open the artifact directory (expects `manifest.json` inside).
    pub fn open(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir).context("loading manifest")?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: std::cell::RefCell::new(HashMap::new()),
        })
    }

    /// Default artifact directory: `$QUARTZ_ARTIFACTS` or `./artifacts`.
    pub fn artifact_dir() -> PathBuf {
        std::env::var("QUARTZ_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Open the default directory.
    pub fn open_default() -> Result<Runtime> {
        Runtime::open(&Self::artifact_dir())
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest
            .artifacts
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))
    }

    /// Compile (or fetch cached) an artifact's executable.
    pub fn load(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(Rc::clone(exe));
        }
        let spec = self.spec(name)?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling artifact '{name}'"))?,
        );
        self.cache.borrow_mut().insert(name.to_string(), Rc::clone(&exe));
        Ok(exe)
    }

    /// Execute an artifact. All graphs are lowered with `return_tuple=True`,
    /// so the single output buffer is a tuple that we decompose into
    /// `spec.outputs` literals.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let spec_inputs = self.spec(name)?.inputs.len();
        crate::ensure!(
            inputs.len() == spec_inputs,
            "artifact '{name}' wants {spec_inputs} inputs, got {}",
            inputs.len()
        );
        let exe = self.load(name)?;
        let result = exe.execute::<xla::Literal>(inputs)?;
        let tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }

    /// Number of artifacts compiled so far (cache introspection for tests).
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

//! In-tree stand-in for the `xla` (PJRT) bindings.
//!
//! The offline build environment has no XLA toolchain, so this module
//! provides the exact API surface `client.rs` / `literal.rs` consume:
//!
//! * [`Literal`] is **fully functional** — real typed storage with
//!   `vec1` / `scalar` / `reshape` / `to_vec` / tuple support, so every
//!   literal-marshalling code path (and its tests) works without XLA.
//! * [`PjRtClient::compile`] returns a descriptive error: executing HLO
//!   requires the real backend. Callers that need execution (integration
//!   tests, benches, the training CLI) already gate on the artifact bundle
//!   being present, so a stubbed backend degrades to clean skips/errors
//!   rather than build breaks.
//!
//! When a real XLA linkage lands, this file is the single seam to replace.

use std::fmt;
use std::path::Path;

/// Error type mirroring the binding crate's (`std::error::Error`, so `?`
/// converts it into [`crate::util::error::Error`]).
#[derive(Debug)]
pub struct Error {
    pub msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const NO_BACKEND: &str =
    "PJRT/XLA backend unavailable in this build (quartz was compiled without the \
     native XLA toolchain; HLO execution requires it)";

/// Element types a [`Literal`] can hold (the two the artifact contract uses).
#[derive(Clone, Debug, PartialEq)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Sealed-ish helper: native element types convertible to/from literals.
pub trait NativeType: Copy {
    fn wrap(v: Vec<Self>) -> LiteralData;
    fn unwrap(d: &LiteralData) -> Option<&[Self]>;
    const DTYPE: &'static str;
}

impl NativeType for f32 {
    fn wrap(v: Vec<f32>) -> LiteralData {
        LiteralData::F32(v)
    }
    fn unwrap(d: &LiteralData) -> Option<&[f32]> {
        match d {
            LiteralData::F32(v) => Some(v),
            _ => None,
        }
    }
    const DTYPE: &'static str = "f32";
}

impl NativeType for i32 {
    fn wrap(v: Vec<i32>) -> LiteralData {
        LiteralData::I32(v)
    }
    fn unwrap(d: &LiteralData) -> Option<&[i32]> {
        match d {
            LiteralData::I32(v) => Some(v),
            _ => None,
        }
    }
    const DTYPE: &'static str = "i32";
}

/// A typed host tensor (array or tuple), matching the binding crate's shape.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    pub dims: Vec<i64>,
    pub data: LiteralData,
}

impl Literal {
    /// Rank-1 literal from a native slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: T::wrap(v.to_vec()) }
    }

    /// Rank-0 f32 literal.
    pub fn scalar(x: f32) -> Literal {
        Literal { dims: Vec::new(), data: LiteralData::F32(vec![x]) }
    }

    fn element_count(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
            LiteralData::Tuple(t) => t.len(),
        }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if matches!(self.data, LiteralData::Tuple(_)) {
            return Err(Error::new("cannot reshape a tuple literal"));
        }
        let want: i64 = dims.iter().product();
        let have = self.element_count() as i64;
        if want != have {
            return Err(Error::new(format!(
                "reshape to {dims:?} needs {want} elements, literal has {have}"
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    /// Copy out as a flat native vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .map(|s| s.to_vec())
            .ok_or_else(|| Error::new(format!("literal is not {}", T::DTYPE)))
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            LiteralData::Tuple(t) => Ok(t),
            _ => Err(Error::new("literal is not a tuple")),
        }
    }
}

/// Parsed-but-not-compiled HLO module text.
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    /// Load HLO text from disk (real parsing happens at compile time in the
    /// actual backend; the stub only validates readability).
    pub fn from_text_file(path: &Path) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("reading {}: {e}", path.display())))?;
        Ok(HloModuleProto { text })
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Clone, Debug)]
pub struct XlaComputation {
    pub proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { proto: proto.clone() }
    }
}

/// Placeholder for a device-resident buffer.
#[derive(Clone, Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::new(NO_BACKEND))
    }
}

/// A compiled executable. Unreachable through the stub client (compilation
/// errors first), but the type must exist for the cache signatures.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(NO_BACKEND))
    }
}

/// The CPU PJRT client. Construction succeeds (so `Runtime::open` works and
/// manifest-only paths like `quartz list` stay functional); compilation is
/// where the stub reports the missing backend.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(NO_BACKEND))
    }

    pub fn platform_name(&self) -> String {
        "cpu (stub — XLA backend not linked)".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims, vec![2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_reshape_validates_count() {
        let l = Literal::vec1(&[1i32, 2, 3]);
        assert!(l.reshape(&[2, 2]).is_err());
        assert!(l.reshape(&[3, 1]).is_ok());
    }

    #[test]
    fn scalar_and_tuple() {
        let s = Literal::scalar(2.5);
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![2.5]);
        let t = Literal {
            dims: Vec::new(),
            data: LiteralData::Tuple(vec![Literal::scalar(1.0), Literal::scalar(2.0)]),
        };
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::scalar(0.0).to_tuple().is_err());
    }

    #[test]
    fn client_reports_missing_backend() {
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation::from_proto(&HloModuleProto { text: String::new() });
        let err = client.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("backend unavailable"), "{err}");
    }
}

//! `artifacts/manifest.json` — the contract between the python compile path
//! and the rust runtime (single source of truth for shapes & inits).

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::fmt;
use std::path::{Path, PathBuf};

#[derive(Debug)]
pub enum ManifestError {
    Io { path: PathBuf, source: std::io::Error },
    Parse(String),
}

impl fmt::Display for ManifestError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ManifestError::Io { path, source } => {
                write!(f, "io error reading {}: {source}", path.display())
            }
            ManifestError::Parse(msg) => write!(f, "manifest parse error: {msg}"),
        }
    }
}

impl std::error::Error for ManifestError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ManifestError::Io { source, .. } => Some(source),
            ManifestError::Parse(_) => None,
        }
    }
}

/// Shape + dtype of one executable input.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One lowered HLO artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: usize,
}

/// One model parameter (name, shape, init std).
#[derive(Clone, Debug)]
pub struct ParamInfo {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub std: f32,
}

/// A registered model: parameter inventory + workload metadata.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub kind: String,
    pub batch: usize,
    pub meta: BTreeMap<String, f64>,
    pub params: Vec<ParamInfo>,
}

impl ModelInfo {
    pub fn shapes(&self) -> Vec<(usize, usize)> {
        self.params.iter().map(|p| (p.rows, p.cols)).collect()
    }

    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).map(|&v| v as usize)
    }

    pub fn n_weights(&self) -> usize {
        self.params.iter().map(|p| p.rows * p.cols).sum()
    }
}

/// The whole manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub models: BTreeMap<String, ModelInfo>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest, ManifestError> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|source| ManifestError::Io { path: path.clone(), source })?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest, ManifestError> {
        let v = Json::parse(text).map_err(|e| ManifestError::Parse(e.to_string()))?;
        let mut out = Manifest::default();

        let arts = v
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .ok_or_else(|| ManifestError::Parse("missing 'artifacts'".into()))?;
        for (name, spec) in arts {
            let file = spec
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| ManifestError::Parse(format!("{name}: missing file")))?
                .to_string();
            let inputs = spec
                .get("inputs")
                .and_then(|i| i.as_arr())
                .ok_or_else(|| ManifestError::Parse(format!("{name}: missing inputs")))?
                .iter()
                .map(|i| {
                    let shape = i
                        .get("shape")
                        .and_then(|s| s.as_arr())
                        .map(|s| s.iter().filter_map(|x| x.as_usize()).collect())
                        .unwrap_or_default();
                    let dtype = i
                        .get("dtype")
                        .and_then(|d| d.as_str())
                        .unwrap_or("float32")
                        .to_string();
                    TensorSpec { shape, dtype }
                })
                .collect();
            let outputs = spec.get("outputs").and_then(|o| o.as_usize()).unwrap_or(1);
            out.artifacts.insert(name.clone(), ArtifactSpec { file, inputs, outputs });
        }

        if let Some(models) = v.get("models").and_then(|m| m.as_obj()) {
            for (name, m) in models {
                let kind = m.get("kind").and_then(|k| k.as_str()).unwrap_or("").to_string();
                let batch = m.get("batch").and_then(|b| b.as_usize()).unwrap_or(1);
                let mut meta = BTreeMap::new();
                if let Some(obj) = m.get("meta").and_then(|x| x.as_obj()) {
                    for (k, val) in obj {
                        if let Some(f) = val.as_f64() {
                            meta.insert(k.clone(), f);
                        }
                    }
                }
                let params = m
                    .get("params")
                    .and_then(|p| p.as_arr())
                    .map(|arr| {
                        arr.iter()
                            .map(|p| ParamInfo {
                                name: p
                                    .get("name")
                                    .and_then(|n| n.as_str())
                                    .unwrap_or("")
                                    .to_string(),
                                rows: p.get("rows").and_then(|r| r.as_usize()).unwrap_or(0),
                                cols: p.get("cols").and_then(|c| c.as_usize()).unwrap_or(0),
                                std: p.get("std").and_then(|s| s.as_f64()).unwrap_or(0.0) as f32,
                            })
                            .collect()
                    })
                    .unwrap_or_default();
                out.models.insert(
                    name.clone(),
                    ModelInfo { name: name.clone(), kind, batch, meta, params },
                );
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": {
        "m.fwd_bwd": {"file": "m.fwd_bwd.hlo.txt",
          "inputs": [{"shape": [4, 3], "dtype": "float32"},
                     {"shape": [8], "dtype": "int32"}],
          "outputs": 2}
      },
      "models": {
        "m": {"kind": "classifier", "batch": 8,
              "meta": {"dim": 3, "classes": 4},
              "params": [{"name": "w0", "rows": 4, "cols": 3, "std": 0.5}]}
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = &m.artifacts["m.fwd_bwd"];
        assert_eq!(a.file, "m.fwd_bwd.hlo.txt");
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[1].dtype, "int32");
        assert_eq!(a.outputs, 2);
        let model = &m.models["m"];
        assert_eq!(model.kind, "classifier");
        assert_eq!(model.meta_usize("classes"), Some(4));
        assert_eq!(model.shapes(), vec![(4, 3)]);
        assert_eq!(model.params[0].std, 0.5);
        assert_eq!(model.n_weights(), 12);
    }

    #[test]
    fn rejects_missing_sections() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
    }

    #[test]
    fn parses_real_manifest_if_built() {
        // Integration sanity against the actual artifacts (skipped when the
        // build step hasn't run).
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.artifacts.contains_key("kernel.quant_roundtrip"));
            assert!(m.models.contains_key("lm_s"));
        }
    }
}

//! Paper-style table rendering + figure series export, and the grouped
//! `quartz codecs` registry listing.

pub mod codecs;
pub mod table;

pub use table::Table;

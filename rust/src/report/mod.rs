//! Paper-style table rendering + figure series export.

pub mod table;

pub use table::Table;

//! Aligned-text table renderer matching the paper's row structure.

use std::fmt::Write as _;
use std::path::Path;

/// A simple column-aligned table.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        debug_assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render as aligned monospace text.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line =
            |out: &mut String, cells: &[String]| {
                for i in 0..ncol {
                    let pad = widths[i] - cells[i].chars().count();
                    let _ = write!(out, "| {}{} ", cells[i], " ".repeat(pad));
                }
                let _ = writeln!(out, "|");
            };
        line(&mut out, &self.headers);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&mut out, &sep);
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        println!("{}", self.render());
    }

    /// Also persist as CSV next to the figure dumps.
    pub fn save_csv(&self, path: &Path) -> std::io::Result<()> {
        let mut w = crate::util::csv::CsvWriter::create(
            path,
            &self.headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        )?;
        for row in &self.rows {
            w.row(row)?;
        }
        w.flush()
    }
}

/// Format a metric as the paper does (percent with 2 decimals).
pub fn pct(x: f64) -> String {
    format!("{:.2}", x * 100.0)
}

/// Format bytes as MB with one decimal (paper's memory columns).
pub fn mb(bytes: usize) -> String {
    format!("{:.2}", bytes as f64 / 1e6)
}

/// Format seconds with one decimal.
pub fn secs(s: f64) -> String {
    format!("{s:.1}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Tab X", &["Optimizer", "Accuracy", "Memory"]);
        t.row(vec!["SGDM".into(), "74.43".into(), "597.3".into()]);
        t.row(vec!["SGDM + 32-bit Shampoo".into(), "75.02".into(), "1065.2".into()]);
        let r = t.render();
        assert!(r.contains("== Tab X =="));
        let lines: Vec<&str> = r.lines().collect();
        // All body lines same display width.
        assert_eq!(lines[1].len(), lines[3].len());
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    fn formatters() {
        assert_eq!(pct(0.7443), "74.43");
        assert_eq!(mb(64_800_000), "64.80");
        assert_eq!(secs(12.34), "12.3");
    }

    #[test]
    fn csv_roundtrip() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let p = std::env::temp_dir().join("quartz_table_test.csv");
        t.save_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
        std::fs::remove_file(&p).ok();
    }
}

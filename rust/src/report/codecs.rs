//! The `quartz codecs` listing.
//!
//! Renders the four open registries under separate headers — optimizer
//! stacks (`train::registry`), preconditioner codecs (`quant::codec`),
//! refresh policies (`shampoo::scheduler`), and grafts (`optim::grafting`)
//! — and prices every codec's **bytes per element** at a reference
//! preconditioner order, side and root constructors separately (they differ
//! for the Cholesky family). Lives in the library (not `main.rs`) so the
//! CLI output is snapshot-tested in `tests/cli_codecs.rs`.

use crate::optim::grafting;
use crate::quant::codec;
use crate::quant::{BlockQuantizer, CodecCtx, PrecondCodec, QuantConfig};
use crate::report::table::Table;
use crate::shampoo::scheduler;
use crate::train::registry;
use std::sync::Arc;

/// Preconditioner order the bytes-per-element column is priced at. Large
/// enough that block scales amortize like they do in real layers, small
/// enough that building every registered codec stays instant.
pub const REFERENCE_ORDER: usize = 256;

/// Physical bytes per element of one `REFERENCE_ORDER`-sized slot held by
/// `ctor`, measured on a live codec in its initial (`ε·I`) state — byte
/// counts are shape-dependent only, so this equals the steady-state cost.
fn bytes_per_elem(ctor: fn(&CodecCtx) -> Box<dyn PrecondCodec>, ctx: &CodecCtx) -> f64 {
    let mut c = ctor(ctx);
    c.init(REFERENCE_ORDER, 1e-6);
    c.size_bytes() as f64 / (REFERENCE_ORDER * REFERENCE_ORDER) as f64
}

/// Render the full `quartz codecs` listing (four grouped tables).
pub fn codec_listing() -> String {
    let mut out = String::new();

    let mut t = Table::new("optimizer stacks (train::registry)", &["key", "summary"]);
    for key in registry::stack_keys() {
        let b = registry::lookup(key).unwrap();
        t.row(vec![key.to_string(), b.summary.to_string()]);
    }
    out.push_str(&t.render());
    out.push('\n');

    // The experiment-default quantizer (b=4, B=64, linear-2) with the
    // small-tensor exemption off, so the reference order actually quantizes.
    let q = BlockQuantizer::new(QuantConfig { min_quant_elems: 0, ..Default::default() });
    let ctx = CodecCtx::new(1e-6, 0.95, Arc::new(q));
    let title =
        format!("preconditioner codecs (quant::codec) — bytes/elem at order {REFERENCE_ORDER}");
    let mut t = Table::new(&title, &["key", "side B/elem", "root B/elem", "summary"]);
    for key in codec::codec_keys() {
        let b = codec::lookup(key).unwrap();
        t.row(vec![
            key.to_string(),
            format!("{:.3}", bytes_per_elem(b.side, &ctx)),
            format!("{:.3}", bytes_per_elem(b.root, &ctx)),
            b.summary.to_string(),
        ]);
    }
    out.push_str(&t.render());
    out.push('\n');

    let mut t = Table::new("refresh policies (shampoo::scheduler)", &["key", "summary"]);
    for key in scheduler::scheduler_keys() {
        let b = scheduler::lookup(key).unwrap();
        t.row(vec![key.to_string(), b.summary.to_string()]);
    }
    out.push_str(&t.render());
    out.push('\n');

    let mut t = Table::new("grafts (optim::grafting)", &["key", "summary"]);
    for key in grafting::graft_keys() {
        let b = grafting::lookup(key).unwrap();
        t.row(vec![key.to_string(), b.summary.to_string()]);
    }
    out.push_str(&t.render());
    out
}

//! SGD / SGD-with-momentum update rules (paper's SGDM base, App. C.3:
//! lr 0.1, momentum 0.9, coupled L2 weight decay).

use super::optimizer::{Hyper, OptimizerKind, ParamState};
use crate::linalg::Matrix;

/// One SGD(M) step: `g' = g + wd·w`; `m ← µ·m + g'`; `w ← w − lr·m`
/// (or `w ← w − lr·g'` without momentum).
pub fn step(
    h: &Hyper,
    kind: OptimizerKind,
    s: &mut ParamState,
    w: &mut Matrix,
    g: &Matrix,
    lr: f32,
) {
    s.t += 1;
    let use_momentum = kind == OptimizerKind::Sgdm && h.momentum > 0.0;
    if use_momentum {
        if s.m.is_none() {
            s.m = Some(Matrix::zeros(g.rows(), g.cols()));
        }
        let m = s.m.as_mut().unwrap();
        for i in 0..g.data().len() {
            let gi = g.data()[i] + h.weight_decay * w.data()[i];
            let mi = h.momentum * m.data()[i] + gi;
            m.data_mut()[i] = mi;
            w.data_mut()[i] -= lr * mi;
        }
    } else {
        for i in 0..g.data().len() {
            let gi = g.data()[i] + h.weight_decay * w.data()[i];
            w.data_mut()[i] -= lr * gi;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hyper(momentum: f32, wd: f32) -> Hyper {
        Hyper { lr: 0.1, momentum, weight_decay: wd, ..Default::default() }
    }

    #[test]
    fn plain_sgd_step() {
        let mut w = Matrix::from_rows(&[&[1.0]]);
        let g = Matrix::from_rows(&[&[0.5]]);
        let mut s = ParamState::default();
        step(&hyper(0.0, 0.0), OptimizerKind::Sgd, &mut s, &mut w, &g, 0.1);
        assert!((w[(0, 0)] - 0.95).abs() < 1e-7);
        assert!(s.m.is_none(), "no momentum buffer for plain sgd");
    }

    #[test]
    fn momentum_accumulates() {
        let mut w = Matrix::from_rows(&[&[0.0]]);
        let g = Matrix::from_rows(&[&[1.0]]);
        let mut s = ParamState::default();
        let h = hyper(0.9, 0.0);
        // step1: m=1, w=-0.1 ; step2: m=1.9, w=-0.29
        step(&h, OptimizerKind::Sgdm, &mut s, &mut w, &g, 0.1);
        assert!((w[(0, 0)] + 0.1).abs() < 1e-7);
        step(&h, OptimizerKind::Sgdm, &mut s, &mut w, &g, 0.1);
        assert!((w[(0, 0)] + 0.29).abs() < 1e-6);
    }

    #[test]
    fn weight_decay_is_coupled() {
        let mut w = Matrix::from_rows(&[&[2.0]]);
        let g = Matrix::from_rows(&[&[0.0]]);
        let mut s = ParamState::default();
        step(&hyper(0.0, 0.5), OptimizerKind::Sgd, &mut s, &mut w, &g, 0.1);
        // g' = 0 + 0.5·2 = 1 → w = 2 − 0.1 = 1.9
        assert!((w[(0, 0)] - 1.9).abs() < 1e-7);
    }

    #[test]
    fn converges_on_quadratic() {
        // minimize f(w) = 0.5‖w − 3‖² with exact gradients.
        let mut w = Matrix::from_rows(&[&[0.0]]);
        let mut s = ParamState::default();
        let h = hyper(0.9, 0.0);
        for _ in 0..200 {
            let g = Matrix::from_rows(&[&[w[(0, 0)] - 3.0]]);
            step(&h, OptimizerKind::Sgdm, &mut s, &mut w, &g, 0.05);
        }
        assert!((w[(0, 0)] - 3.0).abs() < 1e-3, "w={}", w[(0, 0)]);
    }
}

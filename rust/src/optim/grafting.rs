//! Learning-rate grafting (paper Eq. (13) and Algorithm 2 step 15, from
//! Agarwal et al. [1]): rescale the preconditioned gradient so its
//! Frobenius norm matches the raw gradient's, decoupling Shampoo's
//! direction from the base optimizer's step-size calibration.

use crate::linalg::{fro_norm, Matrix};

/// `G̃ = (‖G‖_F / ‖Ĝ‖_F) · Ĝ`, in place on `precond`.
/// If `‖Ĝ‖_F = 0` the preconditioned gradient is left as-is (zero).
pub fn graft(raw: &Matrix, precond: &mut Matrix) {
    let ng = fro_norm(raw);
    let np = fro_norm(precond);
    if np > 0.0 && ng.is_finite() && np.is_finite() {
        let s = (ng / np) as f32;
        precond.scale(s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn preserves_raw_norm() {
        let mut rng = Rng::new(1);
        let raw = Matrix::randn(6, 8, 2.0, &mut rng);
        let mut pre = Matrix::randn(6, 8, 0.001, &mut rng);
        graft(&raw, &mut pre);
        assert!((fro_norm(&pre) - fro_norm(&raw)).abs() / fro_norm(&raw) < 1e-5);
    }

    #[test]
    fn preserves_direction() {
        let raw = Matrix::from_rows(&[&[10.0, 0.0]]);
        let mut pre = Matrix::from_rows(&[&[0.0, 0.5]]);
        graft(&raw, &mut pre);
        assert_eq!(pre[(0, 0)], 0.0, "direction unchanged");
        assert!((pre[(0, 1)] - 10.0).abs() < 1e-6);
    }

    #[test]
    fn zero_precond_is_noop() {
        let raw = Matrix::from_rows(&[&[1.0]]);
        let mut pre = Matrix::from_rows(&[&[0.0]]);
        graft(&raw, &mut pre);
        assert_eq!(pre[(0, 0)], 0.0);
    }
}

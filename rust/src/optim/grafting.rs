//! Learning-rate grafting: the magnitude/direction split of paper Eq. (13)
//! and Algorithm 2 step 15 (from Agarwal et al. [1]), grown into the
//! scalable-Shampoo graft family — the preconditioned direction is rescaled
//! per layer, per step, to the step magnitude a reference first-order method
//! would have taken, decoupling Shampoo's direction from the base
//! optimizer's step-size calibration.
//!
//! * [`Graft`] — the per-layer policy trait: [`Graft::magnitude`] returns
//!   the target norm for this step; stateful variants (AdaGrad / RMSProp)
//!   own a per-layer accumulator that is counted in [`Graft::size_bytes`],
//!   priced by `metrics::MemoryModel`, and round-tripped through
//!   [`Graft::write_state`] / [`Graft::read_state`] so faulted/async
//!   resumes stay bit-identical.
//! * Built-ins: `none` (grafting disabled), `sgd` (`‖G‖_F` — the classic
//!   Eq. 13 norm graft, bit-identical to the historical [`graft`] free
//!   function), `adagrad` (`‖G / (√(Σ G∘G) + ε)‖_F`), `rmsprop` (the same
//!   magnitude over an EMA second moment), and `sqrt-n` (`√(rows·cols)`,
//!   the dimension-normalized constant graft).
//! * A string-keyed registry mirroring `quant::codec` and
//!   `shampoo::scheduler` — [`register`] / [`lookup`] / [`graft_keys`];
//!   `ShampooConfig::graft` selects by key from the CLI / TOML specs.
//! * [`apply_graft`] — the shared application step: compute the magnitude,
//!   rescale the preconditioned gradient to it, and **screen** non-finite
//!   magnitudes or scale factors through the health ledger
//!   (`grads_screened`) instead of silently no-opping — a preconditioned
//!   gradient that overflowed to `Inf` must never reach the base update.

use crate::linalg::{fro_norm, Matrix};
use crate::metrics::HealthLedger;
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::error::Result;
use std::sync::{Mutex, OnceLock};

/// `G̃ = (‖G‖_F / ‖Ĝ‖_F) · Ĝ`, in place on `precond`.
/// If `‖Ĝ‖_F = 0` the preconditioned gradient is left as-is (zero).
///
/// The historical entry point (and the sequential-oracle reference): the
/// registered `sgd` graft reproduces it bit-for-bit on finite inputs. New
/// call sites should go through [`apply_graft`], which additionally screens
/// non-finite norms through the health counters.
pub fn graft(raw: &Matrix, precond: &mut Matrix) {
    let ng = fro_norm(raw);
    let np = fro_norm(precond);
    if np > 0.0 && ng.is_finite() && np.is_finite() {
        let s = (ng / np) as f32;
        precond.scale(s);
    }
}

/// A layer-wise grafting policy: per step, the target magnitude the
/// preconditioned update is rescaled to.
///
/// One instance serves ONE layer for the optimizer's lifetime — stateful
/// variants keep their accumulator here. [`Graft::magnitude`] is called
/// exactly once per layer per step (the executor guarantees this: the fast
/// path iterates layers sequentially, and on refresh steps the graft rides
/// inside the layer's apply lock, which runs exactly once per layer per
/// step), so accumulators advance deterministically regardless of thread
/// count.
pub trait Graft: Send {
    /// Registry key (also the config-file spelling).
    fn key(&self) -> &'static str;

    /// Target magnitude for this step's update, advancing any internal
    /// accumulator state. `raw` is the layer's raw (unpreconditioned)
    /// gradient.
    fn magnitude(&mut self, raw: &Matrix) -> f64;

    /// Persistent accumulator bytes (0 for stateless variants) — counted in
    /// `Shampoo::shampoo_state_bytes` and by `metrics::MemoryModel`.
    fn size_bytes(&self) -> usize {
        0
    }

    /// Serialize the accumulator state (nothing for stateless variants).
    fn write_state(&self, _out: &mut ByteWriter) {}

    /// Inverse of [`Graft::write_state`] on a freshly built graft.
    fn read_state(&mut self, _r: &mut ByteReader<'_>) -> Result<()> {
        Ok(())
    }
}

/// Rescale `precond` to the graft's target magnitude, in place:
/// `G̃ = (m(G) / ‖Ĝ‖_F) · Ĝ`. Returns `false` when the update was screened
/// — a non-finite magnitude, a non-finite `‖Ĝ‖_F` (the preconditioned
/// product overflowed), or a scale factor that overflows `f32` is counted
/// on `ledger` (`grads_screened`) and the caller must skip the base update
/// entirely, exactly like the executor's raw-gradient screen: the poisoned
/// step never happened for this layer.
///
/// The `none` graft short-circuits (no norms computed, `precond`
/// untouched); a zero `‖Ĝ‖_F` leaves the zero update as-is. On finite
/// inputs the `sgd` graft is bit-identical to the historical [`graft`]
/// free function.
pub fn apply_graft(
    g: &mut dyn Graft,
    raw: &Matrix,
    precond: &mut Matrix,
    ledger: &HealthLedger,
) -> bool {
    if g.key() == "none" {
        return true;
    }
    let m = g.magnitude(raw);
    let np = fro_norm(precond);
    if !m.is_finite() || !np.is_finite() {
        ledger.grad_screened();
        return false;
    }
    if np > 0.0 {
        let s = (m / np) as f32;
        if !s.is_finite() {
            ledger.grad_screened();
            return false;
        }
        precond.scale(s);
    }
    true
}

/// Hyperparameters the stateful grafts need (threaded from `ShampooConfig`
/// by the Shampoo driver: `eps` is the config's ε, `beta` its EMA β).
#[derive(Clone, Copy, Debug)]
pub struct GraftParams {
    /// Denominator stabilizer ε in `G / (√acc + ε)`.
    pub eps: f32,
    /// EMA momentum for the `rmsprop` second-moment accumulator.
    pub beta: f32,
}

impl Default for GraftParams {
    fn default() -> Self {
        GraftParams { eps: 1e-6, beta: 0.95 }
    }
}

/// Grafting disabled: [`apply_graft`] short-circuits without touching the
/// preconditioned gradient (`cfg.grafting = false` routes here).
struct NoGraft;

impl Graft for NoGraft {
    fn key(&self) -> &'static str {
        "none"
    }

    fn magnitude(&mut self, _raw: &Matrix) -> f64 {
        1.0
    }
}

/// The classic Eq. 13 norm graft: `m(G) = ‖G‖_F` (an SGD step's magnitude).
struct SgdGraft;

impl Graft for SgdGraft {
    fn key(&self) -> &'static str {
        "sgd"
    }

    fn magnitude(&mut self, raw: &Matrix) -> f64 {
        fro_norm(raw)
    }
}

/// The dimension-normalized constant graft: `m(G) = √(rows·cols)` — every
/// step has unit RMS magnitude regardless of the gradient's scale.
struct SqrtNGraft {
    magnitude: f64,
}

impl Graft for SqrtNGraft {
    fn key(&self) -> &'static str {
        "sqrt-n"
    }

    fn magnitude(&mut self, _raw: &Matrix) -> f64 {
        self.magnitude
    }
}

/// Second-moment accumulator grafts: `adagrad` (`acc ← acc + G∘G`) and
/// `rmsprop` (`acc ← β·acc + (1−β)·G∘G`), both with
/// `m(G) = ‖G / (√acc + ε)‖_F` — the step magnitude the corresponding
/// diagonal method would have taken. The accumulator is per-layer
/// persistent state: counted in [`Graft::size_bytes`] and serialized.
struct AccumGraft {
    key: &'static str,
    acc: Matrix,
    eps: f32,
    /// `None` = AdaGrad sum; `Some(β)` = RMSProp EMA.
    beta: Option<f32>,
}

impl Graft for AccumGraft {
    fn key(&self) -> &'static str {
        self.key
    }

    fn magnitude(&mut self, raw: &Matrix) -> f64 {
        debug_assert_eq!((raw.rows(), raw.cols()), (self.acc.rows(), self.acc.cols()));
        let mut sum = 0.0f64;
        for (a, &g) in self.acc.data_mut().iter_mut().zip(raw.data()) {
            *a = match self.beta {
                None => *a + g * g,
                Some(b) => b * *a + (1.0 - b) * (g * g),
            };
            let ratio = g / (a.sqrt() + self.eps);
            sum += ratio as f64 * ratio as f64;
        }
        sum.sqrt()
    }

    fn size_bytes(&self) -> usize {
        self.acc.size_bytes()
    }

    fn write_state(&self, out: &mut ByteWriter) {
        self.acc.write_bytes(out);
    }

    fn read_state(&mut self, r: &mut ByteReader<'_>) -> Result<()> {
        let acc = Matrix::read_bytes(r)?;
        crate::ensure!(
            (acc.rows(), acc.cols()) == (self.acc.rows(), self.acc.cols()),
            "graft accumulator is {}x{}, layer expects {}x{}",
            acc.rows(),
            acc.cols(),
            self.acc.rows(),
            self.acc.cols()
        );
        self.acc = acc;
        Ok(())
    }
}

/// One registry entry (mirrors `quant::codec::CodecBuilder` and
/// `shampoo::scheduler::SchedulerBuilder`).
#[derive(Clone, Copy)]
pub struct GraftBuilder {
    /// Canonical key (the `graft` config spelling).
    pub key: &'static str,
    /// One-line description for CLI/docs listings.
    pub summary: &'static str,
    /// Build a fresh per-layer graft for a `rows×cols` parameter.
    pub build: fn(rows: usize, cols: usize, params: &GraftParams) -> Box<dyn Graft>,
}

fn build_none(_rows: usize, _cols: usize, _p: &GraftParams) -> Box<dyn Graft> {
    Box::new(NoGraft)
}

fn build_sgd(_rows: usize, _cols: usize, _p: &GraftParams) -> Box<dyn Graft> {
    Box::new(SgdGraft)
}

fn build_adagrad(rows: usize, cols: usize, p: &GraftParams) -> Box<dyn Graft> {
    Box::new(AccumGraft { key: "adagrad", acc: Matrix::zeros(rows, cols), eps: p.eps, beta: None })
}

fn build_rmsprop(rows: usize, cols: usize, p: &GraftParams) -> Box<dyn Graft> {
    Box::new(AccumGraft {
        key: "rmsprop",
        acc: Matrix::zeros(rows, cols),
        eps: p.eps,
        beta: Some(p.beta),
    })
}

fn build_sqrt_n(rows: usize, cols: usize, _p: &GraftParams) -> Box<dyn Graft> {
    Box::new(SqrtNGraft { magnitude: ((rows * cols) as f64).sqrt() })
}

fn builtin_grafts() -> Vec<GraftBuilder> {
    vec![
        GraftBuilder {
            key: "none",
            summary: "grafting disabled (preconditioned update used as-is)",
            build: build_none,
        },
        GraftBuilder {
            key: "sgd",
            summary: "rescale to ‖G‖_F (Eq. 13 norm graft, the default)",
            build: build_sgd,
        },
        GraftBuilder {
            key: "adagrad",
            summary: "rescale to ‖G/(√(ΣG∘G)+ε)‖_F (per-layer AdaGrad state)",
            build: build_adagrad,
        },
        GraftBuilder {
            key: "rmsprop",
            summary: "rescale to ‖G/(√acc+ε)‖_F over an EMA second moment",
            build: build_rmsprop,
        },
        GraftBuilder {
            key: "sqrt-n",
            summary: "rescale to √(rows·cols) (dimension-normalized constant)",
            build: build_sqrt_n,
        },
    ]
}

fn registry() -> &'static Mutex<Vec<GraftBuilder>> {
    static REGISTRY: OnceLock<Mutex<Vec<GraftBuilder>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(builtin_grafts()))
}

/// Register a graft under a new key. Returns `false` (unchanged registry)
/// if the key is taken — built-ins cannot be shadowed.
pub fn register(builder: GraftBuilder) -> bool {
    let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    if reg.iter().any(|b| b.key == builder.key) {
        return false;
    }
    reg.push(builder);
    true
}

/// Look up a graft builder by key.
///
/// ```
/// use quartz::optim::grafting::{graft_keys, lookup};
///
/// let b = lookup("adagrad").expect("built-in graft");
/// assert_eq!(b.key, "adagrad");
/// assert!(lookup("no-such-graft").is_none());
/// // Built-ins come first in the key listing.
/// assert_eq!(
///     graft_keys()[..5].to_vec(),
///     vec!["none", "sgd", "adagrad", "rmsprop", "sqrt-n"]
/// );
/// ```
pub fn lookup(key: &str) -> Option<GraftBuilder> {
    let reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    reg.iter().find(|b| b.key == key).copied()
}

/// All registered keys, built-ins first.
pub fn graft_keys() -> Vec<&'static str> {
    let reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    reg.iter().map(|b| b.key).collect()
}

/// Build the graft `key` for a `rows×cols` layer, panicking with the key on
/// an unknown one — configs can reference runtime-registered grafts, so
/// this is a runtime binding by design (same contract as the codec and
/// scheduler registries).
pub fn build_for(key: &str, rows: usize, cols: usize, params: &GraftParams) -> Box<dyn Graft> {
    let b = lookup(key).unwrap_or_else(|| panic!("graft '{key}' is not registered"));
    (b.build)(rows, cols, params)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn preserves_raw_norm() {
        let mut rng = Rng::new(1);
        let raw = Matrix::randn(6, 8, 2.0, &mut rng);
        let mut pre = Matrix::randn(6, 8, 0.001, &mut rng);
        graft(&raw, &mut pre);
        assert!((fro_norm(&pre) - fro_norm(&raw)).abs() / fro_norm(&raw) < 1e-5);
    }

    #[test]
    fn preserves_direction() {
        let raw = Matrix::from_rows(&[&[10.0, 0.0]]);
        let mut pre = Matrix::from_rows(&[&[0.0, 0.5]]);
        graft(&raw, &mut pre);
        assert_eq!(pre[(0, 0)], 0.0, "direction unchanged");
        assert!((pre[(0, 1)] - 10.0).abs() < 1e-6);
    }

    #[test]
    fn zero_precond_is_noop() {
        let raw = Matrix::from_rows(&[&[1.0]]);
        let mut pre = Matrix::from_rows(&[&[0.0]]);
        graft(&raw, &mut pre);
        assert_eq!(pre[(0, 0)], 0.0);
    }

    #[test]
    fn sgd_graft_is_bit_identical_to_free_function() {
        let mut rng = Rng::new(7);
        let ledger = HealthLedger::new();
        let mut g = build_for("sgd", 9, 5, &GraftParams::default());
        for _ in 0..6 {
            let raw = Matrix::randn(9, 5, 1.3, &mut rng);
            let mut a = Matrix::randn(9, 5, 0.4, &mut rng);
            let mut b = a.clone();
            graft(&raw, &mut a);
            assert!(apply_graft(g.as_mut(), &raw, &mut b, &ledger));
            assert_eq!(a.max_abs_diff(&b), 0.0, "sgd graft must match the Eq. 13 function");
        }
        assert_eq!(g.size_bytes(), 0, "sgd graft is stateless");
        assert_eq!(ledger.take().grads_screened, 0);
    }

    #[test]
    fn none_graft_leaves_update_untouched() {
        let ledger = HealthLedger::new();
        let mut g = build_for("none", 3, 3, &GraftParams::default());
        let raw = Matrix::from_rows(&[&[100.0, 0.0, 0.0]]);
        let mut pre = Matrix::from_rows(&[&[0.0, 0.25, 0.0]]);
        let snap = pre.clone();
        assert!(apply_graft(g.as_mut(), &raw, &mut pre, &ledger));
        assert_eq!(pre.max_abs_diff(&snap), 0.0);
    }

    #[test]
    fn adagrad_accumulates_and_rmsprop_decays() {
        let p = GraftParams { eps: 1e-6, beta: 0.5 };
        let mut ada = build_for("adagrad", 1, 2, &p);
        let mut rms = build_for("rmsprop", 1, 2, &p);
        let g = Matrix::from_rows(&[&[2.0, 0.0]]);
        // AdaGrad: acc = 4 then 8 → m = |2/√4| then |2/√8| (ε-shifted).
        let m1 = ada.magnitude(&g);
        let m2 = ada.magnitude(&g);
        assert!((m1 - 1.0).abs() < 1e-5, "m1={m1}");
        assert!((m2 - 2.0 / 8.0f64.sqrt()).abs() < 1e-5, "m2={m2}");
        // RMSProp: acc = 0.5·0 + 0.5·4 = 2, then 0.5·2 + 0.5·4 = 3.
        let r1 = rms.magnitude(&g);
        let r2 = rms.magnitude(&g);
        assert!((r1 - 2.0 / 2.0f64.sqrt()).abs() < 1e-5, "r1={r1}");
        assert!((r2 - 2.0 / 3.0f64.sqrt()).abs() < 1e-5, "r2={r2}");
        // Both price their accumulator.
        assert_eq!(ada.size_bytes(), 2 * 4);
        assert_eq!(rms.size_bytes(), 2 * 4);
    }

    #[test]
    fn sqrt_n_magnitude_is_dimension_constant() {
        let mut g = build_for("sqrt-n", 3, 12, &GraftParams::default());
        let raw = Matrix::from_rows(&[&[1e9, 0.0]]);
        assert_eq!(g.magnitude(&raw), 36.0f64.sqrt());
        assert_eq!(g.size_bytes(), 0);
    }

    #[test]
    fn accumulator_round_trips_byte_exactly() {
        let mut rng = Rng::new(3);
        let p = GraftParams::default();
        let mut g = build_for("adagrad", 4, 6, &p);
        for _ in 0..5 {
            g.magnitude(&Matrix::randn(4, 6, 1.0, &mut rng));
        }
        let mut w = ByteWriter::new();
        g.write_state(&mut w);
        let bytes = w.into_bytes();
        let mut fresh = build_for("adagrad", 4, 6, &p);
        fresh.read_state(&mut ByteReader::new(&bytes)).unwrap();
        let mut w2 = ByteWriter::new();
        fresh.write_state(&mut w2);
        assert_eq!(bytes, w2.into_bytes(), "re-serialization must be byte-identical");
        // The restored accumulator continues the trajectory bit-identically.
        let probe = Matrix::randn(4, 6, 1.0, &mut rng);
        assert_eq!(g.magnitude(&probe).to_bits(), fresh.magnitude(&probe).to_bits());
        // Shape-mismatched state errors instead of corrupting.
        let mut wrong = build_for("adagrad", 6, 4, &p);
        assert!(wrong.read_state(&mut ByteReader::new(&bytes)).is_err());
    }

    #[test]
    fn non_finite_precond_is_screened_not_applied() {
        // The PR 8 guard contract: a preconditioned gradient that
        // overflowed to Inf (or a non-finite magnitude) is screened through
        // the ledger and the caller skips the base update — the historical
        // free function silently no-opped and let the poison through.
        let ledger = HealthLedger::new();
        let mut g = build_for("sgd", 1, 2, &GraftParams::default());
        let raw = Matrix::from_rows(&[&[1.0, 2.0]]);
        let mut pre = Matrix::from_rows(&[&[f32::INFINITY, 0.0]]);
        assert!(!apply_graft(g.as_mut(), &raw, &mut pre, &ledger));
        assert_eq!(ledger.take().grads_screened, 1);
        // Overflowing scale factor (huge magnitude over tiny norm) is
        // likewise screened rather than scaling the update to Inf.
        let mut sq = build_for("sqrt-n", 4000, 4000, &GraftParams::default());
        let mut tiny = Matrix::from_rows(&[&[1e-42f32, 0.0]]);
        assert!(!apply_graft(sq.as_mut(), &raw, &mut tiny, &ledger));
        assert_eq!(ledger.take().grads_screened, 1);
    }

    #[test]
    fn registry_has_builtins_and_rejects_shadowing() {
        for key in ["none", "sgd", "adagrad", "rmsprop", "sqrt-n"] {
            let b = lookup(key).unwrap_or_else(|| panic!("builtin '{key}' missing"));
            assert_eq!(b.key, key);
        }
        assert!(lookup("no-such-graft").is_none());
        let b = lookup("sgd").unwrap();
        assert!(!register(b));
        assert!(graft_keys().starts_with(&["none", "sgd", "adagrad", "rmsprop", "sqrt-n"]));
    }
}

//! The base-optimizer abstraction `F(W, s, Ĝ)` of Algorithm 1/2, and the
//! [`Optimizer`] trait every full optimizer (base or Shampoo-wrapped)
//! implements.

use crate::linalg::Matrix;
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::error::Result;

/// A complete optimizer over a fixed parameter list: one `step` advances
/// every parameter given its gradient. Implemented by [`BaseOptimizer`]
/// (first-order rules) and `shampoo::Shampoo` (preconditioned); the trainer,
/// coordinator, and examples program exclusively against this trait (boxed
/// inside `train::OptimizerStack`), so new optimizers plug in without
/// touching any of them.
///
/// ```
/// use quartz::optim::{BaseOptimizer, Optimizer};
/// use quartz::linalg::Matrix;
///
/// let mut opt = BaseOptimizer::sgd(0.5, 0.0);
/// opt.init(1);
/// let mut params = vec![Matrix::eye(2)];
/// let grads = vec![Matrix::eye(2)];
/// opt.step(&mut params, &grads, 1, 1.0);
/// // One SGD step at lr 0.5 against an identity gradient: 1 − 0.5 = 0.5.
/// assert!((params[0][(0, 0)] - 0.5).abs() < 1e-6);
/// assert_eq!(opt.name(), "SGD");
/// assert_eq!(opt.state_bytes(), 0, "plain SGD keeps no state");
/// ```
pub trait Optimizer: Send {
    /// Allocate per-parameter state for `n_params` parameters. Optimizers
    /// built with shapes up-front may make this a no-op.
    fn init(&mut self, n_params: usize);

    /// Apply one update across all parameters. `k` is the 1-based global
    /// step (drives preconditioner refresh schedules); `lr_scale` is the
    /// LR-schedule multiplier.
    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], k: u64, lr_scale: f32);

    /// Persistent optimizer-state bytes (the paper's memory columns).
    fn state_bytes(&self) -> usize;

    /// Human label for table rows ("SGDM + 4-bit (CQ+EF) Shampoo" style) —
    /// the single naming source for every stack.
    fn name(&self) -> String;

    /// Serialize the full mutable optimizer state (every buffer a resumed
    /// run needs to continue bit-identically) into `out`. Hyperparameters
    /// and structure are NOT serialized — the restoring side rebuilds the
    /// optimizer from its spec first, then calls [`Optimizer::restore_state`]
    /// on the fresh instance. Defaults to unsupported so third-party
    /// optimizers opt in explicitly.
    fn save_state(&self, _out: &mut ByteWriter) -> Result<()> {
        crate::bail!("optimizer {:?} does not support checkpointing", self.name())
    }

    /// Inverse of [`Optimizer::save_state`]: overwrite this freshly built
    /// optimizer's state with the serialized buffers.
    fn restore_state(&mut self, _r: &mut ByteReader<'_>) -> Result<()> {
        crate::bail!("optimizer {:?} does not support checkpointing", self.name())
    }

    /// Install (or clear) a deterministic fault-injection plan. Optimizers
    /// without an internal refresh pipeline have nothing to force-fail, so
    /// the default ignores the plan — gradient corruption happens upstream
    /// in the trainer either way.
    fn set_fault_plan(&mut self, _plan: Option<&crate::util::fault::FaultPlan>) {}

    /// Cumulative numerical-health counters (screened gradients, fallback
    /// ladder rungs, quarantine transitions). Defaults to all-zero for
    /// optimizers with no guarded refresh pipeline.
    fn health_stats(&self) -> crate::metrics::HealthStats {
        Default::default()
    }
}

/// Which first-order rule is in use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimizerKind {
    Sgd,
    Sgdm,
    Adam,
    AdamW,
    RmsProp,
}

impl OptimizerKind {
    pub fn name(&self) -> &'static str {
        match self {
            OptimizerKind::Sgd => "sgd",
            OptimizerKind::Sgdm => "sgdm",
            OptimizerKind::Adam => "adam",
            OptimizerKind::AdamW => "adamw",
            OptimizerKind::RmsProp => "rmsprop",
        }
    }

    /// Parse the config-file spelling (inverse of [`OptimizerKind::name`]).
    pub fn parse(s: &str) -> Option<OptimizerKind> {
        match s {
            "sgd" => Some(OptimizerKind::Sgd),
            "sgdm" => Some(OptimizerKind::Sgdm),
            "adam" => Some(OptimizerKind::Adam),
            "adamw" => Some(OptimizerKind::AdamW),
            "rmsprop" => Some(OptimizerKind::RmsProp),
            _ => None,
        }
    }

    /// f32 state matrices kept per parameter (the memory model uses this:
    /// SGDM keeps 1 momentum buffer, Adam/AdamW keep 2, RMSProp keeps 1).
    pub fn state_slots(&self) -> usize {
        match self {
            OptimizerKind::Sgd => 0,
            OptimizerKind::Sgdm | OptimizerKind::RmsProp => 1,
            OptimizerKind::Adam | OptimizerKind::AdamW => 2,
        }
    }
}

/// Hyperparameters shared across the rules (unused fields ignored).
#[derive(Clone, Copy, Debug)]
pub struct Hyper {
    pub lr: f32,
    pub momentum: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for Hyper {
    fn default() -> Self {
        Hyper { lr: 1e-3, momentum: 0.9, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0 }
    }
}

/// Per-parameter optimizer state.
#[derive(Clone, Debug, Default)]
pub struct ParamState {
    /// First moment / momentum buffer.
    pub m: Option<Matrix>,
    /// Second moment buffer.
    pub v: Option<Matrix>,
    /// Per-rule step counter (for bias correction).
    pub t: u64,
}

impl ParamState {
    pub fn size_bytes(&self) -> usize {
        self.m.as_ref().map(|x| x.size_bytes()).unwrap_or(0)
            + self.v.as_ref().map(|x| x.size_bytes()).unwrap_or(0)
    }
}

/// A concrete base optimizer instance over a fixed set of parameters.
#[derive(Clone, Debug)]
pub struct BaseOptimizer {
    pub kind: OptimizerKind,
    pub hyper: Hyper,
    pub states: Vec<ParamState>,
}

impl BaseOptimizer {
    pub fn new(kind: OptimizerKind, hyper: Hyper) -> BaseOptimizer {
        BaseOptimizer { kind, hyper, states: Vec::new() }
    }

    /// SGD with momentum + coupled L2 weight decay (paper's CNN setting).
    pub fn sgdm(lr: f32, momentum: f32, weight_decay: f32) -> BaseOptimizer {
        BaseOptimizer::new(
            OptimizerKind::Sgdm,
            Hyper { lr, momentum, weight_decay, ..Default::default() },
        )
    }

    /// Plain SGD.
    pub fn sgd(lr: f32, weight_decay: f32) -> BaseOptimizer {
        BaseOptimizer::new(OptimizerKind::Sgd, Hyper { lr, weight_decay, ..Default::default() })
    }

    /// AdamW (decoupled weight decay) — the paper's ViT/Swin/LLM setting.
    pub fn adamw(lr: f32, beta1: f32, beta2: f32, eps: f32, weight_decay: f32) -> BaseOptimizer {
        BaseOptimizer::new(
            OptimizerKind::AdamW,
            Hyper { lr, beta1, beta2, eps, weight_decay, ..Default::default() },
        )
    }

    /// Adam (coupled L2).
    pub fn adam(lr: f32, beta1: f32, beta2: f32, eps: f32, weight_decay: f32) -> BaseOptimizer {
        BaseOptimizer::new(
            OptimizerKind::Adam,
            Hyper { lr, beta1, beta2, eps, weight_decay, ..Default::default() },
        )
    }

    /// RMSProp (Tab. 8 ablation).
    pub fn rmsprop(lr: f32, alpha: f32, eps: f32, weight_decay: f32) -> BaseOptimizer {
        BaseOptimizer::new(
            OptimizerKind::RmsProp,
            Hyper { lr, beta2: alpha, eps, weight_decay, ..Default::default() },
        )
    }

    /// Allocate state for `n` parameters (lazily sized on first step).
    pub fn init(&mut self, n_params: usize) {
        self.states = vec![ParamState::default(); n_params];
    }

    /// Apply one update to parameter `idx`: `W ← F(W, s, g)` with the
    /// effective learning rate `lr = hyper.lr · lr_scale` (the schedule
    /// multiplier).
    pub fn step_param(&mut self, idx: usize, w: &mut Matrix, g: &Matrix, lr_scale: f32) {
        assert!(idx < self.states.len(), "optimizer not initialized for param {idx}");
        Self::step_one(&self.hyper, self.kind, &mut self.states[idx], w, g, lr_scale);
    }

    /// The rule dispatch with explicit state — lets callers holding disjoint
    /// `&mut ParamState`s (e.g. Shampoo's parallel per-layer loop) update
    /// parameters concurrently without borrowing the whole optimizer.
    pub fn step_one(
        hyper: &Hyper,
        kind: OptimizerKind,
        state: &mut ParamState,
        w: &mut Matrix,
        g: &Matrix,
        lr_scale: f32,
    ) {
        let lr = hyper.lr * lr_scale;
        match kind {
            OptimizerKind::Sgd | OptimizerKind::Sgdm => {
                super::sgd::step(hyper, kind, state, w, g, lr)
            }
            OptimizerKind::Adam | OptimizerKind::AdamW => {
                super::adam::step(hyper, kind, state, w, g, lr)
            }
            OptimizerKind::RmsProp => super::rmsprop::step(hyper, state, w, g, lr),
        }
    }

    /// Total optimizer-state bytes currently held.
    pub fn state_bytes(&self) -> usize {
        self.states.iter().map(|s| s.size_bytes()).sum()
    }

    /// Serialize every [`ParamState`] (presence-flagged `m`/`v` buffers plus
    /// the bias-correction counter). `kind`/`hyper` are spec-derived and not
    /// written — see [`Optimizer::save_state`].
    pub fn write_state(&self, out: &mut ByteWriter) {
        out.put_u64(self.states.len() as u64);
        for s in &self.states {
            for buf in [&s.m, &s.v] {
                match buf {
                    Some(m) => {
                        out.put_u8(1);
                        m.write_bytes(out);
                    }
                    None => out.put_u8(0),
                }
            }
            out.put_u64(s.t);
        }
    }

    /// Inverse of [`BaseOptimizer::write_state`].
    pub fn read_state(&mut self, r: &mut ByteReader<'_>) -> Result<()> {
        let n = r.get_len()?;
        let mut states = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            let mut st = ParamState::default();
            for buf in [&mut st.m, &mut st.v] {
                *buf = match r.get_u8()? {
                    0 => None,
                    _ => Some(Matrix::read_bytes(r)?),
                };
            }
            st.t = r.get_u64()?;
            states.push(st);
        }
        self.states = states;
        Ok(())
    }
}

impl Optimizer for BaseOptimizer {
    fn init(&mut self, n_params: usize) {
        BaseOptimizer::init(self, n_params);
    }

    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], _k: u64, lr_scale: f32) {
        for (i, (w, g)) in params.iter_mut().zip(grads.iter()).enumerate() {
            self.step_param(i, w, g, lr_scale);
        }
    }

    fn state_bytes(&self) -> usize {
        BaseOptimizer::state_bytes(self)
    }

    fn name(&self) -> String {
        self.kind.name().to_uppercase()
    }

    fn save_state(&self, out: &mut ByteWriter) -> Result<()> {
        self.write_state(out);
        Ok(())
    }

    fn restore_state(&mut self, r: &mut ByteReader<'_>) -> Result<()> {
        self.read_state(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_state_slots() {
        assert_eq!(OptimizerKind::Sgd.state_slots(), 0);
        assert_eq!(OptimizerKind::Sgdm.state_slots(), 1);
        assert_eq!(OptimizerKind::AdamW.state_slots(), 2);
    }

    #[test]
    fn state_bytes_counts_buffers() {
        let mut opt = BaseOptimizer::adamw(1e-3, 0.9, 0.999, 1e-8, 0.01);
        opt.init(1);
        let mut w = Matrix::zeros(10, 10);
        let g = Matrix::eye(10);
        assert_eq!(opt.state_bytes(), 0);
        opt.step_param(0, &mut w, &g, 1.0);
        assert_eq!(opt.state_bytes(), 2 * 10 * 10 * 4);
    }

    #[test]
    fn kind_parse_inverts_name() {
        for kind in [
            OptimizerKind::Sgd,
            OptimizerKind::Sgdm,
            OptimizerKind::Adam,
            OptimizerKind::AdamW,
            OptimizerKind::RmsProp,
        ] {
            assert_eq!(OptimizerKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(OptimizerKind::parse("lion"), None);
    }

    #[test]
    fn base_state_round_trips_byte_exactly() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(7);
        let mut opt = BaseOptimizer::adamw(1e-3, 0.9, 0.999, 1e-8, 0.01);
        opt.init(2);
        let mut params = vec![Matrix::zeros(6, 4), Matrix::zeros(3, 3)];
        for k in 1..=5 {
            let grads: Vec<Matrix> =
                params.iter().map(|p| Matrix::randn(p.rows(), p.cols(), 1.0, &mut rng)).collect();
            Optimizer::step(&mut opt, &mut params, &grads, k, 1.0);
        }
        let mut w = ByteWriter::new();
        opt.save_state(&mut w).unwrap();
        let bytes = w.into_bytes();
        let mut fresh = BaseOptimizer::adamw(1e-3, 0.9, 0.999, 1e-8, 0.01);
        fresh.restore_state(&mut ByteReader::new(&bytes)).unwrap();
        let mut w2 = ByteWriter::new();
        fresh.save_state(&mut w2).unwrap();
        assert_eq!(bytes, w2.into_bytes(), "re-serialization must be byte-identical");
        assert_eq!(fresh.states.len(), 2);
        assert_eq!(fresh.states[0].t, 5);
        // Truncated input errors instead of panicking.
        assert!(fresh.restore_state(&mut ByteReader::new(&bytes[..bytes.len() - 3])).is_err());
    }

    #[test]
    fn trait_step_matches_per_param_loop() {
        let mut a = BaseOptimizer::sgd(0.5, 0.0);
        let mut b = BaseOptimizer::sgd(0.5, 0.0);
        a.init(2);
        b.init(2);
        let grads = vec![Matrix::eye(3), Matrix::eye_scaled(3, 2.0)];
        let mut pa = vec![Matrix::zeros(3, 3), Matrix::zeros(3, 3)];
        let mut pb = pa.clone();
        Optimizer::step(&mut a, &mut pa, &grads, 1, 1.0);
        for (i, (w, g)) in pb.iter_mut().zip(grads.iter()).enumerate() {
            b.step_param(i, w, g, 1.0);
        }
        for (x, y) in pa.iter().zip(pb.iter()) {
            assert_eq!(x.max_abs_diff(y), 0.0);
        }
        assert_eq!(Optimizer::name(&a), "SGD");
    }
}

//! Learning-rate schedules (App. C.3: cosine annealing with linear warmup).

/// Schedule returning a multiplier on the base learning rate.
#[derive(Clone, Copy, Debug)]
pub enum LrSchedule {
    /// Constant 1.0.
    Constant,
    /// Linear warmup over `warmup` steps, then cosine decay to `min_frac`
    /// of the base LR at `total` steps (the paper's image/LLM schedule).
    CosineWarmup { warmup: u64, total: u64, min_frac: f32 },
    /// Step decay: multiply by `gamma` every `every` steps.
    StepDecay { every: u64, gamma: f32 },
}

impl LrSchedule {
    /// Multiplier at `step` (0-based).
    pub fn scale(&self, step: u64) -> f32 {
        match *self {
            LrSchedule::Constant => 1.0,
            LrSchedule::CosineWarmup { warmup, total, min_frac } => {
                if warmup > 0 && step < warmup {
                    (step + 1) as f32 / warmup as f32
                } else {
                    let total = total.max(warmup + 1);
                    let t = (step - warmup) as f32 / (total - warmup) as f32;
                    let t = t.clamp(0.0, 1.0);
                    let cos = 0.5 * (1.0 + (std::f32::consts::PI * t).cos());
                    min_frac + (1.0 - min_frac) * cos
                }
            }
            LrSchedule::StepDecay { every, gamma } => gamma.powi((step / every.max(1)) as i32),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly() {
        let s = LrSchedule::CosineWarmup { warmup: 10, total: 100, min_frac: 0.0 };
        assert!((s.scale(0) - 0.1).abs() < 1e-6);
        assert!((s.scale(4) - 0.5).abs() < 1e-6);
        assert!((s.scale(9) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cosine_decays_to_min() {
        let s = LrSchedule::CosineWarmup { warmup: 0, total: 100, min_frac: 0.1 };
        assert!((s.scale(0) - 1.0).abs() < 1e-4);
        assert!((s.scale(100) - 0.1).abs() < 1e-4);
        assert!(s.scale(50) < s.scale(25));
    }

    #[test]
    fn monotone_after_warmup() {
        let s = LrSchedule::CosineWarmup { warmup: 5, total: 50, min_frac: 0.0 };
        let mut prev = f32::INFINITY;
        for step in 5..=50 {
            let v = s.scale(step);
            assert!(v <= prev + 1e-6);
            prev = v;
        }
    }

    #[test]
    fn step_decay() {
        let s = LrSchedule::StepDecay { every: 10, gamma: 0.5 };
        assert_eq!(s.scale(0), 1.0);
        assert_eq!(s.scale(10), 0.5);
        assert_eq!(s.scale(25), 0.25);
    }

    #[test]
    fn constant_is_one() {
        assert_eq!(LrSchedule::Constant.scale(12345), 1.0);
    }
}

//! RMSProp update rule (paper Tab. 8 ablation base).

use super::optimizer::{Hyper, ParamState};
use crate::linalg::Matrix;

/// One RMSProp step: `v ← α·v + (1−α)·g²`; `w ← w − lr·g/(√v + ε)`.
/// `α` is carried in `Hyper::beta2`; weight decay is coupled L2.
pub fn step(h: &Hyper, s: &mut ParamState, w: &mut Matrix, g: &Matrix, lr: f32) {
    s.t += 1;
    if s.v.is_none() {
        s.v = Some(Matrix::zeros(g.rows(), g.cols()));
    }
    let v = s.v.as_mut().unwrap();
    let vdat = v.data_mut();
    let wdat = w.data_mut();
    let gdat = g.data();
    for i in 0..gdat.len() {
        let gi = gdat[i] + h.weight_decay * wdat[i];
        vdat[i] = h.beta2 * vdat[i] + (1.0 - h.beta2) * gi * gi;
        wdat[i] -= lr * gi / (vdat[i].sqrt() + h.eps);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hyper() -> Hyper {
        Hyper { lr: 1e-2, beta2: 0.99, eps: 1e-8, ..Default::default() }
    }

    #[test]
    fn normalizes_gradient_scale() {
        // Large and small gradients produce comparable first-step sizes.
        let mut w1 = Matrix::from_rows(&[&[0.0]]);
        let mut w2 = Matrix::from_rows(&[&[0.0]]);
        let mut s1 = ParamState::default();
        let mut s2 = ParamState::default();
        step(&hyper(), &mut s1, &mut w1, &Matrix::from_rows(&[&[100.0]]), 1e-2);
        step(&hyper(), &mut s2, &mut w2, &Matrix::from_rows(&[&[0.001]]), 1e-2);
        let r = (w1[(0, 0)] / w2[(0, 0)]).abs();
        assert!((0.5..2.0).contains(&r), "ratio={r}");
    }

    #[test]
    fn converges_on_quadratic() {
        let mut w = Matrix::from_rows(&[&[5.0]]);
        let mut s = ParamState::default();
        for _ in 0..2000 {
            let g = Matrix::from_rows(&[&[w[(0, 0)] + 1.0]]);
            step(&hyper(), &mut s, &mut w, &g, 5e-3);
        }
        assert!((w[(0, 0)] + 1.0).abs() < 1e-2, "w={}", w[(0, 0)]);
    }

    #[test]
    fn single_state_buffer() {
        let mut w = Matrix::zeros(2, 2);
        let mut s = ParamState::default();
        step(&hyper(), &mut s, &mut w, &Matrix::eye(2), 1e-2);
        assert!(s.m.is_none());
        assert_eq!(s.size_bytes(), 4 * 4);
    }
}

//! First-order base optimizers `F` (paper Algorithm 1, step 16).
//!
//! Shampoo wraps a base optimizer: the preconditioned (and grafted)
//! gradient replaces the raw gradient fed to `F`. We implement the bases the
//! paper evaluates — SGDM (Tab. 3/4), AdamW (Tab. 3–6), RMSProp (Tab. 8) —
//! plus plain SGD and Adam, cosine/warmup LR schedules, and the grafting
//! trick of Eq. (13) [1].

pub mod optimizer;
pub mod sgd;
pub mod adam;
pub mod rmsprop;
pub mod grafting;
pub mod schedule;

pub use grafting::graft;
pub use optimizer::{BaseOptimizer, OptimizerKind, ParamState};
pub use schedule::LrSchedule;

//! The optimizer API: the [`Optimizer`] trait plus the first-order base
//! rules `F` (paper Algorithm 1, step 16).
//!
//! Every full optimizer — a base rule alone or Shampoo wrapping one —
//! implements [`Optimizer`] (`step`/`state_bytes`/`name`); the training
//! loop, coordinator, and examples see only that trait, boxed inside
//! `train::OptimizerStack` and constructed by string key through
//! `train::registry`. The concrete bases are the ones the paper evaluates —
//! SGDM (Tab. 3/4), AdamW (Tab. 3–6), RMSProp (Tab. 8) — plus plain SGD and
//! Adam, cosine/warmup LR schedules, and the grafting trick of Eq. (13).

pub mod optimizer;
pub mod sgd;
pub mod adam;
pub mod rmsprop;
pub mod grafting;
pub mod schedule;

pub use grafting::{apply_graft, graft, Graft, GraftBuilder, GraftParams};
pub use optimizer::{BaseOptimizer, Optimizer, OptimizerKind, ParamState};
pub use schedule::LrSchedule;

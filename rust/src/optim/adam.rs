//! Adam / AdamW update rules (AdamW is the paper's ViT/Swin/LLM base:
//! lr 1e-3, β₁ 0.9, β₂ 0.999, ε 1e-8, decoupled wd 5e-2).

use super::optimizer::{Hyper, OptimizerKind, ParamState};
use crate::linalg::Matrix;

/// One Adam(W) step with bias correction.
///
/// AdamW applies *decoupled* weight decay (`w ← w − lr·wd·w`); Adam folds
/// `wd·w` into the gradient (coupled L2).
pub fn step(
    h: &Hyper,
    kind: OptimizerKind,
    s: &mut ParamState,
    w: &mut Matrix,
    g: &Matrix,
    lr: f32,
) {
    s.t += 1;
    if s.m.is_none() {
        s.m = Some(Matrix::zeros(g.rows(), g.cols()));
        s.v = Some(Matrix::zeros(g.rows(), g.cols()));
    }
    let t = s.t as i32;
    let bc1 = 1.0 - h.beta1.powi(t);
    let bc2 = 1.0 - h.beta2.powi(t);
    let decoupled = kind == OptimizerKind::AdamW;

    // Split borrows.
    let (m, v) = (s.m.as_mut().unwrap(), s.v.as_mut().unwrap());
    let (mdat, vdat) = (m.data_mut(), v.data_mut());
    let wdat = w.data_mut();
    let gdat = g.data();

    for i in 0..gdat.len() {
        let gi = if decoupled { gdat[i] } else { gdat[i] + h.weight_decay * wdat[i] };
        mdat[i] = h.beta1 * mdat[i] + (1.0 - h.beta1) * gi;
        vdat[i] = h.beta2 * vdat[i] + (1.0 - h.beta2) * gi * gi;
        let mhat = mdat[i] / bc1;
        let vhat = vdat[i] / bc2;
        let mut upd = lr * mhat / (vhat.sqrt() + h.eps);
        if decoupled {
            upd += lr * h.weight_decay * wdat[i];
        }
        wdat[i] -= upd;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hyper(wd: f32) -> Hyper {
        Hyper {
            lr: 1e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: wd,
            ..Default::default()
        }
    }

    #[test]
    fn first_step_is_signed_lr() {
        // With bias correction, step 1 moves by ≈ lr·sign(g).
        let mut w = Matrix::from_rows(&[&[0.0, 0.0]]);
        let g = Matrix::from_rows(&[&[5.0, -0.01]]);
        let mut s = ParamState::default();
        step(&hyper(0.0), OptimizerKind::Adam, &mut s, &mut w, &g, 1e-3);
        assert!((w[(0, 0)] + 1e-3).abs() < 1e-6, "w0={}", w[(0, 0)]);
        assert!((w[(0, 1)] - 1e-3).abs() < 1e-6, "w1={}", w[(0, 1)]);
    }

    #[test]
    fn adamw_decay_is_decoupled() {
        // Zero gradient: Adam leaves w unchanged-ish (coupled decay enters
        // via gradient so moments move), AdamW shrinks w directly.
        let mut w = Matrix::from_rows(&[&[1.0]]);
        let g = Matrix::from_rows(&[&[0.0]]);
        let mut s = ParamState::default();
        step(&hyper(0.1), OptimizerKind::AdamW, &mut s, &mut w, &g, 1e-2);
        assert!((w[(0, 0)] - (1.0 - 1e-2 * 0.1)).abs() < 1e-7);
    }

    #[test]
    fn converges_on_quadratic() {
        let mut w = Matrix::from_rows(&[&[0.0]]);
        let mut s = ParamState::default();
        let h = hyper(0.0);
        for _ in 0..3000 {
            let g = Matrix::from_rows(&[&[w[(0, 0)] - 2.0]]);
            step(&h, OptimizerKind::Adam, &mut s, &mut w, &g, 5e-3);
        }
        assert!((w[(0, 0)] - 2.0).abs() < 1e-2, "w={}", w[(0, 0)]);
    }

    #[test]
    fn allocates_two_buffers() {
        let mut w = Matrix::zeros(3, 3);
        let g = Matrix::eye(3);
        let mut s = ParamState::default();
        step(&hyper(0.0), OptimizerKind::Adam, &mut s, &mut w, &g, 1e-3);
        assert!(s.m.is_some() && s.v.is_some());
        assert_eq!(s.size_bytes(), 2 * 9 * 4);
    }
}

//! Checkpoint/resume persistence: a versioned binary container
//! ([`format`]) and the full training-run snapshot stored inside it
//! ([`train_state`]).
//!
//! The contract is **bit-identical resume**: training N steps produces
//! exactly the same parameter and optimizer-state bytes as training k
//! steps, checkpointing, restoring, and training N−k more — pinned by the
//! oracle tests in `tests/persist_resume.rs`. Everything that feeds the
//! step path round-trips byte-exactly: packed 4-bit codes, scales, EF
//! triangles, eigen factors, momentum buffers, refresh-scheduler metadata,
//! step counters, and the RNG stream position.

pub mod format;
pub mod train_state;

pub use format::{
    latest_valid, list_checkpoints, parse_step_file, prune_checkpoints, spec_hash,
    step_file_name, Checkpoint, FORMAT_VERSION, MAGIC,
};
pub use train_state::TrainState;

//! The versioned, self-describing checkpoint container.
//!
//! Layout (all integers little-endian, via [`crate::util::bytes`]):
//!
//! ```text
//! magic "QUARTZCK" (8)  format version u32  spec-hash u64
//! section count u64
//!   ├─ name (length-prefixed UTF-8)  payload (length-prefixed bytes)
//!   └─ …
//! CRC32 (IEEE) over everything above (4)
//! ```
//!
//! Sections are named and length-prefixed so readers skip what they don't
//! know and future versions can add sections without breaking old files.
//! The spec hash pins a checkpoint to the run spec that produced it — a
//! resume against a different spec (other model, codec stack, steps, seed)
//! is rejected up front instead of silently restoring incompatible buffers.
//! Writes go through a temp file + atomic rename, so a crash mid-write
//! leaves either the previous complete file or a `.tmp` that the scanner
//! never picks up; a truncated or bit-flipped file fails the CRC and
//! [`latest_valid`] falls back to the previous checkpoint.

use crate::util::bytes::{crc32, ByteReader, ByteWriter};
use crate::util::error::{Context, Result};
use std::fs;
use std::path::{Path, PathBuf};

/// File magic: identifies a quartz checkpoint regardless of extension.
pub const MAGIC: [u8; 8] = *b"QUARTZCK";

/// Current container format version.
pub const FORMAT_VERSION: u32 = 1;

/// FNV-1a over a spec-identity string — the hash pinned into every
/// checkpoint header. Stable across platforms and releases (unlike
/// `DefaultHasher`), cheap, and collision-safe enough for a guard whose
/// job is catching *accidental* spec drift.
pub fn spec_hash(identity: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in identity.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// An in-memory checkpoint: spec hash + named byte sections.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub spec_hash: u64,
    sections: Vec<(String, Vec<u8>)>,
}

impl Checkpoint {
    pub fn new(spec_hash: u64) -> Checkpoint {
        Checkpoint { spec_hash, sections: Vec::new() }
    }

    /// Append a named section (names should be unique; lookups return the
    /// first match).
    pub fn add(&mut self, name: &str, payload: Vec<u8>) {
        self.sections.push((name.to_string(), payload));
    }

    /// Borrow a section's payload, erroring with the section name if absent.
    pub fn section(&self, name: &str) -> Result<&[u8]> {
        self.sections
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, p)| p.as_slice())
            .with_context(|| format!("checkpoint has no '{name}' section"))
    }

    pub fn section_names(&self) -> Vec<&str> {
        self.sections.iter().map(|(n, _)| n.as_str()).collect()
    }

    /// Serialize to the on-disk layout (header + sections + trailing CRC).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_u64(u64::from_le_bytes(MAGIC));
        w.put_u32(FORMAT_VERSION);
        w.put_u64(self.spec_hash);
        w.put_u64(self.sections.len() as u64);
        for (name, payload) in &self.sections {
            w.put_str(name);
            w.put_bytes(payload);
        }
        let crc = crc32(w.as_slice());
        w.put_u32(crc);
        w.into_bytes()
    }

    /// Parse + validate the full container: CRC first (so any truncation or
    /// corruption is one uniform error), then magic, version, and sections.
    pub fn from_bytes(data: &[u8]) -> Result<Checkpoint> {
        crate::ensure!(data.len() >= 24, "checkpoint too short ({} bytes)", data.len());
        let (body, tail) = data.split_at(data.len() - 4);
        let want = u32::from_le_bytes([tail[0], tail[1], tail[2], tail[3]]);
        let got = crc32(body);
        crate::ensure!(got == want, "checkpoint CRC mismatch (got {got:08x}, want {want:08x})");
        let mut r = ByteReader::new(body);
        let magic = r.get_u64()?;
        crate::ensure!(magic == u64::from_le_bytes(MAGIC), "not a quartz checkpoint (bad magic)");
        let version = r.get_u32()?;
        crate::ensure!(
            version == FORMAT_VERSION,
            "unsupported checkpoint format version {version} (this build reads {FORMAT_VERSION})"
        );
        let spec_hash = r.get_u64()?;
        let n = r.get_len()?;
        let mut sections = Vec::with_capacity(n.min(1 << 16));
        for _ in 0..n {
            let name = r.get_str()?;
            let payload = r.get_bytes()?.to_vec();
            sections.push((name, payload));
        }
        r.finish()?;
        Ok(Checkpoint { spec_hash, sections })
    }

    /// Write atomically: serialize to `<path>.tmp`, fsync, rename over
    /// `path`. A crash at any point leaves either the old complete file or
    /// an orphaned `.tmp` (which the `step-*.ckpt` scanners ignore).
    pub fn write_atomic(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
        let tmp = path.with_extension("tmp");
        let bytes = self.to_bytes();
        {
            use std::io::Write;
            let mut f = fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(&bytes).with_context(|| format!("writing {}", tmp.display()))?;
            f.sync_all().with_context(|| format!("syncing {}", tmp.display()))?;
        }
        fs::rename(&tmp, path)
            .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))
    }

    /// Read + validate one checkpoint file.
    pub fn read_file(path: &Path) -> Result<Checkpoint> {
        let data =
            fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        Checkpoint::from_bytes(&data).with_context(|| format!("parsing {}", path.display()))
    }
}

/// Canonical checkpoint file name for a step: `step-00001200.ckpt`
/// (zero-padded so lexicographic order == step order).
pub fn step_file_name(step: u64) -> String {
    format!("step-{step:08}.ckpt")
}

/// Inverse of [`step_file_name`].
pub fn parse_step_file(name: &str) -> Option<u64> {
    name.strip_prefix("step-")?.strip_suffix(".ckpt")?.parse().ok()
}

/// All `step-*.ckpt` files in `dir`, sorted ascending by step. A missing
/// directory is an empty list, not an error (nothing to resume from).
pub fn list_checkpoints(dir: &Path) -> Vec<(u64, PathBuf)> {
    let Ok(entries) = fs::read_dir(dir) else { return Vec::new() };
    let mut out: Vec<(u64, PathBuf)> = entries
        .filter_map(|e| {
            let e = e.ok()?;
            let step = parse_step_file(e.file_name().to_str()?)?;
            Some((step, e.path()))
        })
        .collect();
    out.sort_by_key(|&(step, _)| step);
    out
}

/// The newest checkpoint in `dir` that passes CRC + header validation and
/// matches `spec_hash`. Invalid files (truncated write at crash time,
/// corruption) and stale spec hashes are skipped — the scan falls back to
/// the next-newest until one validates. `Ok(None)` when nothing usable
/// exists.
pub fn latest_valid(dir: &Path, spec_hash: u64) -> Result<Option<(u64, Checkpoint)>> {
    for (step, path) in list_checkpoints(dir).into_iter().rev() {
        match Checkpoint::read_file(&path) {
            Ok(ck) if ck.spec_hash == spec_hash => return Ok(Some((step, ck))),
            Ok(ck) => {
                eprintln!(
                    "persist: skipping {} (spec hash {:016x} != expected {:016x})",
                    path.display(),
                    ck.spec_hash,
                    spec_hash
                );
            }
            Err(e) => {
                eprintln!("persist: skipping invalid checkpoint {}: {e:#}", path.display());
            }
        }
    }
    Ok(None)
}

/// Retention: delete all but the newest `keep` checkpoints in `dir`,
/// returning how many were removed. `keep == 0` disables pruning (keep
/// everything); removal errors are ignored — a file that refuses to die
/// only costs disk, while failing the training step over it would cost the
/// run. Invalid/corrupt files still count toward recency here (pruning is
/// name-based); [`latest_valid`] remains the arbiter of what is loadable,
/// so `keep` should comfortably exceed the number of trailing corrupt
/// files a crash can plausibly leave (≥ 2 in practice).
pub fn prune_checkpoints(dir: &Path, keep: usize) -> usize {
    if keep == 0 {
        return 0;
    }
    let ckpts = list_checkpoints(dir);
    let excess = ckpts.len().saturating_sub(keep);
    let mut removed = 0;
    for (_, path) in ckpts.into_iter().take(excess) {
        if fs::remove_file(&path).is_ok() {
            removed += 1;
        }
    }
    removed
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        let mut ck = Checkpoint::new(spec_hash("run-1|model|cq-ef|100|7"));
        ck.add("meta", vec![1, 2, 3]);
        ck.add("params", (0..200u16).flat_map(|x| x.to_le_bytes()).collect());
        ck
    }

    #[test]
    fn container_round_trips() {
        let ck = sample();
        let bytes = ck.to_bytes();
        let back = Checkpoint::from_bytes(&bytes).unwrap();
        assert_eq!(back, ck);
        assert_eq!(back.section_names(), vec!["meta", "params"]);
        assert_eq!(back.section("meta").unwrap(), &[1, 2, 3]);
        assert!(back.section("nope").is_err());
        // Serialization is deterministic.
        assert_eq!(back.to_bytes(), bytes);
    }

    #[test]
    fn corruption_and_truncation_fail_crc() {
        let bytes = sample().to_bytes();
        // Flip one bit anywhere in the body → CRC mismatch.
        for pos in [0, 8, bytes.len() / 2, bytes.len() - 5] {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x10;
            let err = format!("{:#}", Checkpoint::from_bytes(&bad).unwrap_err());
            assert!(err.contains("CRC") || err.contains("magic"), "pos {pos}: {err}");
        }
        // Every strict prefix fails (truncated write).
        for cut in [0, 10, bytes.len() - 1] {
            assert!(Checkpoint::from_bytes(&bytes[..cut]).is_err(), "prefix {cut}");
        }
    }

    #[test]
    fn version_and_magic_are_checked() {
        let ck = sample();
        let mut bytes = ck.to_bytes();
        // Patch the version field (offset 8..12) and re-seal the CRC so
        // only the version check can reject it.
        bytes[8] = 99;
        let n = bytes.len();
        let crc = crc32(&bytes[..n - 4]).to_le_bytes();
        bytes[n - 4..].copy_from_slice(&crc);
        let err = format!("{:#}", Checkpoint::from_bytes(&bytes).unwrap_err());
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn atomic_write_and_latest_valid_scan() {
        let dir = std::env::temp_dir().join(format!("quartz-fmt-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let hash = spec_hash("scan-test");
        for step in [100u64, 200, 300] {
            let mut ck = Checkpoint::new(hash);
            ck.add("meta", step.to_le_bytes().to_vec());
            ck.write_atomic(&dir.join(step_file_name(step))).unwrap();
        }
        let (step, ck) = latest_valid(&dir, hash).unwrap().unwrap();
        assert_eq!(step, 300);
        assert_eq!(ck.section("meta").unwrap(), &300u64.to_le_bytes());

        // Truncate the newest (simulated crash mid-write that somehow kept
        // the final name): the scan must fall back to step 200.
        let newest = dir.join(step_file_name(300));
        let full = fs::read(&newest).unwrap();
        fs::write(&newest, &full[..full.len() / 2]).unwrap();
        let (step, _) = latest_valid(&dir, hash).unwrap().unwrap();
        assert_eq!(step, 200);

        // A different spec hash matches nothing.
        assert!(latest_valid(&dir, hash ^ 1).unwrap().is_none());
        // Missing directory → clean None.
        assert!(latest_valid(&dir.join("absent"), hash).unwrap().is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn retention_prunes_oldest_and_scan_still_falls_back() {
        let dir = std::env::temp_dir().join(format!("quartz-prune-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let hash = spec_hash("prune-test");
        for step in [100u64, 200, 300, 400, 500] {
            let mut ck = Checkpoint::new(hash);
            ck.add("meta", step.to_le_bytes().to_vec());
            ck.write_atomic(&dir.join(step_file_name(step))).unwrap();
        }
        // keep == 0 disables pruning entirely.
        assert_eq!(prune_checkpoints(&dir, 0), 0);
        assert_eq!(list_checkpoints(&dir).len(), 5);
        // Keep the newest 3: steps 100 and 200 go.
        assert_eq!(prune_checkpoints(&dir, 3), 2);
        let left: Vec<u64> = list_checkpoints(&dir).iter().map(|&(s, _)| s).collect();
        assert_eq!(left, vec![300, 400, 500]);
        // Pruning below the current count is a no-op.
        assert_eq!(prune_checkpoints(&dir, 3), 0);
        // Corrupt the newest survivor: the newest-valid scan must still
        // fall back within the retained set.
        let newest = dir.join(step_file_name(500));
        let full = fs::read(&newest).unwrap();
        fs::write(&newest, &full[..full.len() - 7]).unwrap();
        let (step, ck) = latest_valid(&dir, hash).unwrap().unwrap();
        assert_eq!(step, 400);
        assert_eq!(ck.section("meta").unwrap(), &400u64.to_le_bytes());
        // Missing directory prunes nothing.
        assert_eq!(prune_checkpoints(&dir.join("absent"), 2), 0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn step_file_names_sort_and_parse() {
        assert_eq!(step_file_name(1200), "step-00001200.ckpt");
        assert_eq!(parse_step_file("step-00001200.ckpt"), Some(1200));
        assert_eq!(parse_step_file("step-00001200.tmp"), None);
        assert_eq!(parse_step_file("notes.txt"), None);
        assert!(step_file_name(999) < step_file_name(1000));
    }

    #[test]
    fn spec_hash_is_stable_fnv1a() {
        // Pinned values: a silent hash-function change would orphan every
        // existing checkpoint.
        assert_eq!(spec_hash(""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(spec_hash("a"), 0xaf63_dc4c_8601_ec8c);
        assert_ne!(spec_hash("run-1"), spec_hash("run-2"));
    }
}

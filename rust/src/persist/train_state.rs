//! The full training-run snapshot stored inside a checkpoint container.
//!
//! A [`TrainState`] holds everything a resumed run needs to continue
//! **bit-identically** from step `step + 1`: parameters, the serialized
//! optimizer state (every codec payload, EF triangle, momentum buffer, and
//! refresh counter — see [`crate::optim::Optimizer::save_state`]), the
//! trainer's RNG stream position, the metric curves accumulated so far, and
//! the wall/optimizer time already spent (so resumed runs report end-to-end
//! totals, not just the tail).

use super::format::{list_checkpoints, step_file_name, Checkpoint};
use crate::linalg::Matrix;
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::error::{Context, Result};
use std::path::{Path, PathBuf};

/// Snapshot of one training run after `step` completed steps.
#[derive(Clone, Debug)]
pub struct TrainState {
    /// Completed optimizer steps (resume continues at `step + 1`).
    pub step: u64,
    /// Model parameters after `step` steps.
    pub params: Vec<Matrix>,
    /// Opaque optimizer payload ([`crate::train::OptimizerStack::save_state`]).
    pub opt: Vec<u8>,
    /// The trainer's RNG stream state at the end of step `step`.
    pub rng: [u64; 4],
    /// (step, train loss) samples so far.
    pub loss_curve: Vec<(u64, f32)>,
    /// (step, eval metric) samples so far.
    pub eval_curve: Vec<(u64, f64)>,
    /// Wall-clock seconds consumed up to this checkpoint.
    pub wall_secs: f64,
    /// Seconds inside the optimizer up to this checkpoint.
    pub opt_secs: f64,
}

impl TrainState {
    /// Pack into a checkpoint container under the given spec hash.
    pub fn to_checkpoint(&self, spec_hash: u64) -> Checkpoint {
        let mut ck = Checkpoint::new(spec_hash);

        let mut meta = ByteWriter::new();
        meta.put_u64(self.step);
        meta.put_u64s(&self.rng);
        meta.put_f64(self.wall_secs);
        meta.put_f64(self.opt_secs);
        ck.add("meta", meta.into_bytes());

        let mut params = ByteWriter::new();
        params.put_u64(self.params.len() as u64);
        for p in &self.params {
            p.write_bytes(&mut params);
        }
        ck.add("params", params.into_bytes());

        ck.add("opt", self.opt.clone());

        let mut curves = ByteWriter::new();
        curves.put_u64(self.loss_curve.len() as u64);
        for &(k, v) in &self.loss_curve {
            curves.put_u64(k);
            curves.put_f32(v);
        }
        curves.put_u64(self.eval_curve.len() as u64);
        for &(k, v) in &self.eval_curve {
            curves.put_u64(k);
            curves.put_f64(v);
        }
        ck.add("curves", curves.into_bytes());
        ck
    }

    /// Unpack from a validated container.
    pub fn from_checkpoint(ck: &Checkpoint) -> Result<TrainState> {
        let mut meta = ByteReader::new(ck.section("meta")?);
        let step = meta.get_u64()?;
        let rng_v = meta.get_u64s()?;
        crate::ensure!(rng_v.len() == 4, "rng state has {} words, want 4", rng_v.len());
        let rng = [rng_v[0], rng_v[1], rng_v[2], rng_v[3]];
        let wall_secs = meta.get_f64()?;
        let opt_secs = meta.get_f64()?;
        meta.finish()?;

        let mut pr = ByteReader::new(ck.section("params")?);
        let n = pr.get_len()?;
        let mut params = Vec::with_capacity(n.min(1 << 16));
        for _ in 0..n {
            params.push(Matrix::read_bytes(&mut pr)?);
        }
        pr.finish()?;

        let opt = ck.section("opt")?.to_vec();

        let mut cr = ByteReader::new(ck.section("curves")?);
        let nl = cr.get_len()?;
        let mut loss_curve = Vec::with_capacity(nl.min(1 << 20));
        for _ in 0..nl {
            loss_curve.push((cr.get_u64()?, cr.get_f32()?));
        }
        let ne = cr.get_len()?;
        let mut eval_curve = Vec::with_capacity(ne.min(1 << 20));
        for _ in 0..ne {
            eval_curve.push((cr.get_u64()?, cr.get_f64()?));
        }
        cr.finish()?;

        Ok(TrainState { step, params, opt, rng, loss_curve, eval_curve, wall_secs, opt_secs })
    }

    /// Write `dir/step-NNNNNNNN.ckpt` atomically; returns the path.
    pub fn save(&self, dir: &Path, spec_hash: u64) -> Result<PathBuf> {
        let path = dir.join(step_file_name(self.step));
        self.to_checkpoint(spec_hash)
            .write_atomic(&path)
            .with_context(|| format!("saving checkpoint at step {}", self.step))?;
        Ok(path)
    }

    /// Load the newest usable snapshot from `dir` (`None` when nothing
    /// usable exists — fresh start). Scans newest-first and falls back on
    /// *any* failure — CRC, spec-hash mismatch, or a section that no longer
    /// parses — so a corrupt tail never blocks resume.
    pub fn load_latest(dir: &Path, spec_hash: u64) -> Result<Option<TrainState>> {
        for (_, path) in list_checkpoints(dir).into_iter().rev() {
            let parsed = Checkpoint::read_file(&path).and_then(|ck| {
                crate::ensure!(
                    ck.spec_hash == spec_hash,
                    "spec hash {:016x} != expected {spec_hash:016x}",
                    ck.spec_hash
                );
                TrainState::from_checkpoint(&ck)
            });
            match parsed {
                Ok(st) => return Ok(Some(st)),
                Err(e) => {
                    eprintln!("persist: skipping checkpoint {}: {e:#}", path.display());
                }
            }
        }
        Ok(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::persist::format::spec_hash;
    use crate::util::rng::Rng;

    fn sample(step: u64) -> TrainState {
        let mut rng = Rng::new(step);
        TrainState {
            step,
            params: vec![Matrix::randn(6, 4, 1.0, &mut rng), Matrix::randn(3, 3, 1.0, &mut rng)],
            opt: vec![9, 8, 7, 6],
            rng: rng.state(),
            loss_curve: vec![(10, 0.5), (20, 0.25)],
            eval_curve: vec![(20, 0.9)],
            wall_secs: 1.5,
            opt_secs: 0.25,
        }
    }

    #[test]
    fn snapshot_round_trips_byte_exactly() {
        let st = sample(20);
        let hash = spec_hash("ts-test");
        let ck = st.to_checkpoint(hash);
        let bytes = ck.to_bytes();
        let back = TrainState::from_checkpoint(&Checkpoint::from_bytes(&bytes).unwrap()).unwrap();
        assert_eq!(back.step, 20);
        assert_eq!(back.rng, st.rng);
        assert_eq!(back.opt, st.opt);
        assert_eq!(back.loss_curve, st.loss_curve);
        assert_eq!(back.eval_curve, st.eval_curve);
        for (a, b) in back.params.iter().zip(st.params.iter()) {
            assert_eq!(a.max_abs_diff(b), 0.0);
        }
        // Re-serialization is byte-identical.
        assert_eq!(back.to_checkpoint(hash).to_bytes(), bytes);
    }

    #[test]
    fn save_load_latest_skips_corrupt_tail() {
        let dir = std::env::temp_dir().join(format!("quartz-ts-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let hash = spec_hash("ts-scan");
        sample(10).save(&dir, hash).unwrap();
        let p20 = sample(20).save(&dir, hash).unwrap();
        // Corrupt the newest file; load_latest must fall back to step 10.
        let full = std::fs::read(&p20).unwrap();
        std::fs::write(&p20, &full[..full.len() - 7]).unwrap();
        let st = TrainState::load_latest(&dir, hash).unwrap().unwrap();
        assert_eq!(st.step, 10);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

//! Rust-side model handling: deterministic parameter initialization from
//! the manifest's parameter inventory (shapes + init std come from
//! `model.py` via `manifest.json` — a single source of truth).

use crate::linalg::Matrix;
use crate::runtime::manifest::ModelInfo;
use crate::util::rng::Rng;

/// Initialize all parameters of a model, seeded and order-stable.
pub fn init_params(model: &ModelInfo, seed: u64) -> Vec<Matrix> {
    let mut root = Rng::new(seed ^ 0x1B17_AC25);
    model
        .params
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let mut rng = root.fork(i as u64);
            if p.std > 0.0 {
                Matrix::randn(p.rows, p.cols, p.std, &mut rng)
            } else {
                Matrix::zeros(p.rows, p.cols)
            }
        })
        .collect()
}

/// Total trainable weights.
pub fn param_count(model: &ModelInfo) -> usize {
    model.n_weights()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ParamInfo;
    use std::collections::BTreeMap;

    fn toy_model() -> ModelInfo {
        ModelInfo {
            name: "toy".into(),
            kind: "classifier".into(),
            batch: 8,
            meta: BTreeMap::new(),
            params: vec![
                ParamInfo { name: "w".into(), rows: 4, cols: 3, std: 0.5 },
                ParamInfo { name: "b".into(), rows: 1, cols: 3, std: 0.0 },
            ],
        }
    }

    #[test]
    fn deterministic_and_shaped() {
        let m = toy_model();
        let a = init_params(&m, 7);
        let b = init_params(&m, 7);
        assert_eq!(a, b);
        assert_eq!(a[0].rows(), 4);
        assert_eq!(a[1], Matrix::zeros(1, 3), "zero-std params start at zero");
    }

    #[test]
    fn different_seeds_differ() {
        let m = toy_model();
        assert_ne!(init_params(&m, 1)[0], init_params(&m, 2)[0]);
    }

    #[test]
    fn std_is_respected() {
        let mut m = toy_model();
        m.params[0].rows = 64;
        m.params[0].cols = 64;
        let p = init_params(&m, 3);
        let var: f64 = p[0].data().iter().map(|&x| (x as f64).powi(2)).sum::<f64>()
            / (64.0 * 64.0);
        assert!((var.sqrt() - 0.5).abs() < 0.05, "std={}", var.sqrt());
    }
}

//! Preconditioner-codec throughput: `store` (quantize) and `load`
//! (dequantize/reconstruct) for every registered `PrecondCodec` at the
//! paper-relevant preconditioner orders 512/1024 (plus 2048 and 4096
//! outside quick mode — the full suite stays CI-smoke-sized), and the
//! scratch-aware
//! `store_into`/`load_into` hot paths that the Shampoo refresh actually
//! drives (arena-backed, zero steady-state allocation).
//!
//! Runs over the registry, so a newly registered codec is benchmarked with
//! zero changes here — the `ec4`/`f16`/`cq-r1` family entered this bench
//! the moment it registered (`ec4`'s store is eigendecomposition-bound; the
//! Jacobi sweep budget in `quant::ec4` is what keeps the large orders
//! tractable). Records land in `BENCH_quartz.json` via the
//! `QUARTZ_BENCH_JSON` hook (see `scripts/harvest_bench.sh`), seeding the
//! codec-throughput regression trajectory that
//! `scripts/bench_regression.sh` diffs run-over-run.
//!
//! Run: `cargo bench --bench bench_codecs` (QUARTZ_BENCH_QUICK=1 for smoke).

use quartz::linalg::{Matrix, ScratchArena};
use quartz::quant::codec::{codec_keys, lookup};
use quartz::quant::{BlockQuantizer, CodecCtx, QuantConfig};
use quartz::util::bench::{black_box, Bencher};
use quartz::util::rng::Rng;
use std::sync::Arc;

fn main() {
    let mut b = Bencher::new();
    let quantizer = BlockQuantizer::new(QuantConfig { min_quant_elems: 0, ..Default::default() });
    let ctx = CodecCtx::new(1e-6, 0.95, Arc::new(quantizer));
    let mut rng = Rng::new(1);

    let quick = std::env::var("QUARTZ_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let orders: &[usize] = if quick { &[512, 1024] } else { &[512, 1024, 2048, 4096] };

    for &n in orders {
        // A well-conditioned SPD input so Cholesky-based codecs take their
        // fast path (the jitter loop would dominate otherwise).
        let g = Matrix::randn(n, n, 1.0, &mut rng);
        let mut spd = quartz::linalg::syrk(&g);
        spd.scale(1.0 / n as f32);
        spd.add_diag(1.0);
        let bytes = (n * n * 4) as f64;

        for key in codec_keys() {
            // ec4's store is a full Jacobi eigendecomposition — O(n³) per
            // sweep — which at order 4096 costs minutes per iteration. The
            // GEMM-trajectory point stays Cholesky/blockwise-family only.
            if n >= 4096 && key == "ec4" {
                continue;
            }
            let builder = lookup(key).expect("registered codec");
            let mut codec = (builder.side)(&ctx);
            b.bench_with_units(&format!("codec_store/{key}/{n}"), Some((bytes, "B")), || {
                codec.store(&spd);
                black_box(codec.size_bytes());
            });
            b.bench_with_units(&format!("codec_load/{key}/{n}"), Some((bytes, "B")), || {
                black_box(codec.load());
            });

            // Arena-backed hot paths (what `Shampoo::step` runs).
            let mut arena = ScratchArena::new();
            let mut out = Matrix::zeros(n, n);
            codec.store_into(&spd, &mut arena);
            b.bench_with_units(&format!("codec_store_into/{key}/{n}"), Some((bytes, "B")), || {
                codec.store_into(&spd, &mut arena);
                black_box(codec.size_bytes());
            });
            b.bench_with_units(&format!("codec_load_into/{key}/{n}"), Some((bytes, "B")), || {
                codec.load_into(&mut out, &mut arena);
                black_box(&out);
            });
        }
    }
}

//! Optimizer-level benchmarks: full Shampoo steps per variant, and the
//! per-phase costs (gram EMA, root refresh, precondition apply).
//!
//! These quantify the paper's Tab. 5/6 claim that compensated Cholesky
//! quantization adds only marginal compute over vanilla quantization.

use quartz::linalg::Matrix;
use quartz::optim::BaseOptimizer;
use quartz::shampoo::{Shampoo, ShampooConfig, ShampooVariant};
use quartz::util::bench::{black_box, Bencher};
use quartz::util::rng::Rng;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::new(3);
    // A realistic analog layer set (mirrors res_mlp_c32).
    let shapes: Vec<(usize, usize)> = vec![(64, 96), (96, 96), (96, 96), (96, 32)];
    let params: Vec<Matrix> =
        shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.3, &mut rng)).collect();
    let grads: Vec<Matrix> =
        shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.1, &mut rng)).collect();

    for (label, variant) in [
        ("32bit", ShampooVariant::Full32),
        ("vq4", ShampooVariant::Vq4),
        ("cq4", ShampooVariant::Cq4 { error_feedback: false }),
        ("cq4_ef", ShampooVariant::Cq4 { error_feedback: true }),
        ("bw8", ShampooVariant::Bw8),
    ] {
        let mk = |t1: u64, t2: u64| {
            let cfg = ShampooConfig {
                variant,
                t1,
                t2,
                max_order: 96,
                quant: quartz::quant::QuantConfig { min_quant_elems: 0, ..Default::default() },
                ..Default::default()
            };
            Shampoo::new(BaseOptimizer::sgdm(0.05, 0.9, 5e-4), cfg, &shapes)
        };

        // Cheap step (between interval boundaries): precondition + base only.
        let mut sh = mk(1_000_000, 1_000_000);
        let mut p = params.clone();
        let mut k = 1u64;
        b.bench(&format!("step_precondition_only/{label}"), || {
            sh.step(&mut p, &grads, k, 1.0);
            k += 1;
            black_box(&p);
        });

        // Gram-update step (k % T1 == 0 every step).
        let mut sh = mk(1, 1_000_000);
        let mut p = params.clone();
        let mut k = 1u64;
        b.bench(&format!("step_with_gram_update/{label}"), || {
            sh.step(&mut p, &grads, k, 1.0);
            k += 1;
            black_box(&p);
        });

        // Root-refresh step (both updates every step — worst case).
        let mut sh = mk(1, 1);
        let mut p = params.clone();
        let mut k = 1u64;
        b.bench(&format!("step_full_refresh/{label}"), || {
            sh.step(&mut p, &grads, k, 1.0);
            k += 1;
            black_box(&p);
        });
    }

    // Base optimizer reference (what Shampoo's overhead is measured against).
    let mut base = BaseOptimizer::sgdm(0.05, 0.9, 5e-4);
    base.init(shapes.len());
    let mut p = params.clone();
    b.bench("sgdm_step_reference", || {
        for (i, (w, g)) in p.iter_mut().zip(grads.iter()).enumerate() {
            base.step_param(i, w, g, 1.0);
        }
        black_box(&p);
    });

    // ---- End-to-end Shampoo::step at a realistic layer mix, per refresh
    // policy (the ROADMAP's step-wall-clock trajectory item). The mix is
    // transformer-ish — tall/wide projections plus square attention-style
    // blocks — so staggering has real units to spread. Mean step time is
    // amortized cost; the p99/p50 gap and the printed spike metrics
    // (max units/step, worst refresh ms) are the latency-flattening
    // evidence: `every-n` concentrates refresh work, `staggered` bounds it
    // at ⌈units/T₂⌉ per step. Quick mode shrinks the mix (CI smoke); full
    // runs use the larger shapes.
    let quick = std::env::var("QUARTZ_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let (mix, max_order): (Vec<(usize, usize)>, usize) = if quick {
        (vec![(256, 64), (64, 256), (128, 128), (128, 128)], 64)
    } else {
        (vec![(1024, 256), (256, 1024), (512, 512), (512, 512)], 256)
    };
    let (t1, t2) = (5u64, 20u64);
    let mut rng = Rng::new(5);
    let mix_params: Vec<Matrix> =
        mix.iter().map(|&(m, n)| Matrix::randn(m, n, 0.3, &mut rng)).collect();
    let mix_grads: Vec<Matrix> =
        mix.iter().map(|&(m, n)| Matrix::randn(m, n, 0.1, &mut rng)).collect();
    for policy in ["every-n", "staggered", "staleness"] {
        let cfg = ShampooConfig {
            variant: ShampooVariant::Cq4 { error_feedback: true },
            t1,
            t2,
            max_order,
            refresh_policy: policy,
            quant: quartz::quant::QuantConfig { min_quant_elems: 0, ..Default::default() },
            ..Default::default()
        };
        let mut sh = Shampoo::new(BaseOptimizer::sgdm(0.05, 0.9, 5e-4), cfg, &mix);
        let mut p = mix_params.clone();
        let mut k = 1u64;
        b.bench(&format!("step_mix/{policy}"), || {
            sh.step(&mut p, &mix_grads, k, 1.0);
            k += 1;
            black_box(&p);
        });
        let s = sh.refresh_stats();
        println!("  step_mix/{policy}: units {} | {}", sh.unit_count(), s.summary());
    }

    // ---- Graft variants at the same mix and cadence. The graft runs on
    // the per-step apply path (never inside refresh units), so its cost is
    // the per-element accumulator update plus two Frobenius norms — these
    // records pin that the stateful variants (adagrad/rmsprop) stay within
    // noise of the default sgd norm graft.
    for graft in ["sgd", "adagrad", "rmsprop"] {
        let cfg = ShampooConfig {
            variant: ShampooVariant::Cq4 { error_feedback: true },
            t1,
            t2,
            max_order,
            graft,
            quant: quartz::quant::QuantConfig { min_quant_elems: 0, ..Default::default() },
            ..Default::default()
        };
        let mut sh = Shampoo::new(BaseOptimizer::sgdm(0.05, 0.9, 5e-4), cfg, &mix);
        let mut p = mix_params.clone();
        let mut k = 1u64;
        b.bench(&format!("step_mix_graft/{graft}"), || {
            sh.step(&mut p, &mix_grads, k, 1.0);
            k += 1;
            black_box(&p);
        });
        let s = sh.refresh_stats();
        println!("  step_mix_graft/{graft}: units {} | {}", sh.unit_count(), s.summary());
    }

    // ---- The async-refresh engine at the same mix, `every-n` cadence (the
    // spike-heaviest schedule): off vs 2 vs 4 worker shards. The headline
    // is the p95/p99 refresh-spike reduction — root recomputation moves off
    // the step thread and lands `max_async_staleness` steps later — while
    // the printed overlap counters (in-flight peak, barrier stalls, publish
    // lag) bound the staleness actually incurred.
    for (label, async_on, shards) in [("off", false, 0usize), ("2", true, 2), ("4", true, 4)] {
        let cfg = ShampooConfig {
            variant: ShampooVariant::Cq4 { error_feedback: true },
            t1,
            t2,
            max_order,
            async_refresh: async_on,
            async_shards: shards,
            max_async_staleness: 2,
            quant: quartz::quant::QuantConfig { min_quant_elems: 0, ..Default::default() },
            ..Default::default()
        };
        let mut sh = Shampoo::new(BaseOptimizer::sgdm(0.05, 0.9, 5e-4), cfg, &mix);
        let mut p = mix_params.clone();
        let mut k = 1u64;
        b.bench(&format!("step_mix_async/{label}"), || {
            sh.step(&mut p, &mix_grads, k, 1.0);
            k += 1;
            black_box(&p);
        });
        let s = sh.refresh_stats();
        println!("  step_mix_async/{label}: units {} | {}", sh.unit_count(), s.summary());
    }

    // ---- Large-model mix (full mode only): order-4096 gradients with
    // max_order-512 preconditioners. Every gram update and precondition
    // apply here is a 512×4096-class product, so this is the step-level
    // view of the packed-panel GEMM tier at model scale — the order-4096
    // point the codec/matmul benches record, seen through `Shampoo::step`.
    if !quick {
        let large: Vec<(usize, usize)> = vec![(4096, 512), (512, 4096)];
        let mut rng = Rng::new(9);
        let large_params: Vec<Matrix> =
            large.iter().map(|&(m, n)| Matrix::randn(m, n, 0.3, &mut rng)).collect();
        let large_grads: Vec<Matrix> =
            large.iter().map(|&(m, n)| Matrix::randn(m, n, 0.1, &mut rng)).collect();
        let cfg = ShampooConfig {
            variant: ShampooVariant::Cq4 { error_feedback: true },
            t1,
            t2,
            max_order: 512,
            refresh_policy: "staggered",
            quant: quartz::quant::QuantConfig { min_quant_elems: 0, ..Default::default() },
            ..Default::default()
        };
        let mut sh = Shampoo::new(BaseOptimizer::sgdm(0.05, 0.9, 5e-4), cfg, &large);
        let mut p = large_params.clone();
        let mut k = 1u64;
        b.bench("step_mix_large/staggered", || {
            sh.step(&mut p, &large_grads, k, 1.0);
            k += 1;
            black_box(&p);
        });
        let s = sh.refresh_stats();
        println!("  step_mix_large/staggered: units {} | {}", sh.unit_count(), s.summary());
    }

    // ---- The codec-family stack keys (ec4 / f16 / cq-r1 today) at the
    // same layer mix, under the staggered spreader (their refresh units are
    // the expensive part — ec4 eigendecomposes per refresh — so the
    // spreading policy is the realistic deployment). The (side, root)
    // pairs come from the registry's declarative codec metadata, so a
    // future family key is benched the moment it registers. Records land
    // in BENCH_quartz.json next to step_mix/<policy>, putting the codecs
    // under the advisory regression gate from day one.
    let family: Vec<(&str, &str, &str)> = quartz::train::registry::stack_keys()
        .into_iter()
        .filter_map(|key| {
            let (side, root) = quartz::train::registry::lookup(key)?.codecs?;
            Some((key, side, root))
        })
        .collect();
    for (label, side, root) in family {
        let cfg = ShampooConfig {
            t1,
            t2,
            max_order,
            refresh_policy: "staggered",
            side_codec: Some(side),
            root_codec: Some(root),
            quant: quartz::quant::QuantConfig { min_quant_elems: 0, ..Default::default() },
            ..Default::default()
        };
        let mut sh = Shampoo::new(BaseOptimizer::sgdm(0.05, 0.9, 5e-4), cfg, &mix);
        let mut p = mix_params.clone();
        let mut k = 1u64;
        b.bench(&format!("step_mix_codec/{label}"), || {
            sh.step(&mut p, &mix_grads, k, 1.0);
            k += 1;
            black_box(&p);
        });
        let s = sh.refresh_stats();
        println!("  step_mix_codec/{label}: units {} | {}", sh.unit_count(), s.summary());
    }
}

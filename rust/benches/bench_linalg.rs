//! Linear-algebra substrate benchmarks: the O(n³) kernels behind every
//! Shampoo preconditioner update (L3 §Perf roofline targets).

use quartz::linalg::schur_newton::SchurNewtonConfig;
use quartz::linalg::{
    cholesky, eig_sym, inverse_pth_root, lambda_max, matmul, matmul_into_planned, syrk, Matrix,
    MatmulPlan,
};
use quartz::util::bench::{black_box, Bencher};
use quartz::util::rng::Rng;

fn spd(n: usize, rng: &mut Rng) -> Matrix {
    let g = Matrix::randn(n, n + 8, 1.0, rng);
    let mut a = syrk(&g);
    a.add_diag(0.5);
    a
}

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::new(2);

    for n in [64usize, 128, 256] {
        let x = Matrix::randn(n, n, 1.0, &mut rng);
        let y = Matrix::randn(n, n, 1.0, &mut rng);
        let flops = (2 * n * n * n) as f64;
        b.bench_with_units(&format!("matmul/{n}x{n}"), Some((flops, "FLOP")), || {
            black_box(matmul(&x, &y));
        });
        let mut out = Matrix::zeros(n, n);
        let mut plan = MatmulPlan::new();
        b.bench_with_units(&format!("matmul_planned/{n}x{n}"), Some((flops, "FLOP")), || {
            matmul_into_planned(&x, &y, &mut out, &mut plan);
            black_box(&out);
        });
        let g = Matrix::randn(n, 64, 1.0, &mut rng);
        b.bench_with_units(&format!("syrk/{n}x64"), Some(((n * n * 64) as f64, "FLOP")), || {
            black_box(syrk(&g));
        });
    }

    for n in [64usize, 128] {
        let a = spd(n, &mut rng);
        b.bench(&format!("cholesky/{n}"), || {
            black_box(cholesky(&a).unwrap());
        });
        b.bench(&format!("lambda_max/{n}"), || {
            black_box(lambda_max(&a, 50));
        });
        let cfg = SchurNewtonConfig::default();
        b.bench(&format!("schur_newton_p4/{n}"), || {
            black_box(inverse_pth_root(&a, &cfg));
        });
    }

    // Jacobi eigensolver (oracle path — used by analysis, not the hot loop).
    let a = spd(64, &mut rng);
    b.bench("eig_sym/64", || {
        black_box(eig_sym(&a, 1e-10, 100));
    });
}

//! Linear-algebra substrate benchmarks: the O(n³) kernels behind every
//! Shampoo preconditioner update (L3 §Perf roofline targets).

use quartz::linalg::schur_newton::SchurNewtonConfig;
use quartz::linalg::{
    cholesky, cholesky_naive, eig_sym, inverse_pth_root_scratch, lambda_max, matmul,
    matmul_into_planned, syrk, Matrix, MatmulPlan, ScratchArena,
};
use quartz::util::bench::{black_box, Bencher};
use quartz::util::rng::Rng;

fn spd(n: usize, rng: &mut Rng) -> Matrix {
    if n <= 512 {
        let g = Matrix::randn(n, n + 8, 1.0, rng);
        let mut a = syrk(&g);
        a.add_diag(0.5);
        a
    } else {
        // Gershgorin-dominant construction: O(n²) setup instead of an
        // O(n³) syrk just to feed the large-order factorization benches.
        let mut a = Matrix::randn(n, n, 1.0, rng);
        a.symmetrize();
        a.add_diag(2.0 * n as f32);
        a
    }
}

/// Textbook i-k-j triple loop, single-threaded — the reference the packed
/// GEMM tier's speedup is measured against (`gemm/*` vs `gemm_naive/*`).
fn naive_matmul_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    out.data_mut().fill(0.0);
    for i in 0..m {
        for p in 0..k {
            let x = a[(i, p)];
            let (brow, orow) = (b.row(p), out.row_mut(i));
            for j in 0..n {
                orow[j] += x * brow[j];
            }
        }
    }
}

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::new(2);
    let quick = std::env::var("QUARTZ_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);

    for n in [64usize, 128, 256] {
        let x = Matrix::randn(n, n, 1.0, &mut rng);
        let y = Matrix::randn(n, n, 1.0, &mut rng);
        let flops = (2 * n * n * n) as f64;
        b.bench_with_units(&format!("matmul/{n}x{n}"), Some((flops, "FLOP")), || {
            black_box(matmul(&x, &y));
        });
        let mut out = Matrix::zeros(n, n);
        let mut plan = MatmulPlan::new();
        b.bench_with_units(&format!("matmul_planned/{n}x{n}"), Some((flops, "FLOP")), || {
            matmul_into_planned(&x, &y, &mut out, &mut plan);
            black_box(&out);
        });
        let g = Matrix::randn(n, 64, 1.0, &mut rng);
        b.bench_with_units(&format!("syrk/{n}x64"), Some(((n * n * 64) as f64, "FLOP")), || {
            black_box(syrk(&g));
        });
    }

    // Packed-panel GEMM tier at gradient/model orders. `gemm_naive/*` is
    // the single-threaded triple-loop reference the tier's speedup is read
    // against (the PR gate: ≥3× at order 1024); orders 2048/4096 are full
    // GEMM trajectory points and stay out of quick mode, like the large
    // Cholesky and codec orders.
    let gemm_orders: &[usize] =
        if quick { &[256, 512, 1024] } else { &[256, 512, 1024, 2048, 4096] };
    for &n in gemm_orders {
        let x = Matrix::randn(n, n, 1.0, &mut rng);
        let y = Matrix::randn(n, n, 1.0, &mut rng);
        let mut out = Matrix::zeros(n, n);
        let mut plan = MatmulPlan::new();
        let flops = (2 * n * n * n) as f64;
        b.bench_with_units(&format!("gemm/{n}x{n}"), Some((flops, "FLOP")), || {
            matmul_into_planned(&x, &y, &mut out, &mut plan);
            black_box(&out);
        });
    }
    let naive_orders: &[usize] = if quick { &[256] } else { &[256, 512, 1024] };
    for &n in naive_orders {
        let x = Matrix::randn(n, n, 1.0, &mut rng);
        let y = Matrix::randn(n, n, 1.0, &mut rng);
        let mut out = Matrix::zeros(n, n);
        let flops = (2 * n * n * n) as f64;
        b.bench_with_units(&format!("gemm_naive/{n}x{n}"), Some((flops, "FLOP")), || {
            naive_matmul_into(&x, &y, &mut out);
            black_box(&out);
        });
    }

    // Naive reference kernel (the small-n path) vs the blocked
    // right-looking factorization at preconditioner orders. The naive loop
    // is O(n³) scalar, so it stops at 512; the blocked kernel carries the
    // trajectory to 2048.
    for n in [128usize, 256, 512] {
        let a = spd(n, &mut rng);
        let flops = (n * n * n / 3) as f64;
        b.bench_with_units(&format!("cholesky_naive/{n}"), Some((flops, "FLOP")), || {
            black_box(cholesky_naive(&a).unwrap());
        });
    }
    // Order 2048 stays out of quick mode (same gate as bench_codecs): a
    // single blocked factorization there is ~2.9 GFLOP and would dominate
    // the CI smoke budget.
    let blocked_orders: &[usize] =
        if quick { &[128, 256, 512, 1024] } else { &[128, 256, 512, 1024, 2048] };
    for &n in blocked_orders {
        let a = spd(n, &mut rng);
        let flops = (n * n * n / 3) as f64;
        b.bench_with_units(&format!("cholesky_blocked/{n}"), Some((flops, "FLOP")), || {
            black_box(cholesky(&a).unwrap());
        });
    }

    for n in [64usize, 128] {
        let a = spd(n, &mut rng);
        b.bench(&format!("lambda_max/{n}"), || {
            black_box(lambda_max(&a, 50));
        });
        let cfg = SchurNewtonConfig::default();
        let mut arena = ScratchArena::new();
        b.bench(&format!("schur_newton_p4/{n}"), || {
            let (x, stats) = inverse_pth_root_scratch(&a, &cfg, &mut arena);
            black_box(stats.iters);
            arena.recycle(x);
        });
    }

    // Jacobi eigensolver (oracle path — used by analysis, not the hot loop).
    let a = spd(64, &mut rng);
    b.bench("eig_sym/64", || {
        black_box(eig_sym(&a, 1e-10, 100));
    });
}

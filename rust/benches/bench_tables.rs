//! End-to-end per-table unit benchmarks: the cost of ONE complete training
//! step (PJRT fwd/bwd + optimizer) for each paper-table configuration.
//! `quartz table --id tabN` regenerates the tables themselves; this bench
//! tracks the per-step cost those tables are built from, per variant —
//! including the interval-amortized cost at the paper's T1/T2 ratios.
//!
//! Requires `make artifacts`; prints SKIP otherwise.

use quartz::data::synthetic::{ClusterDataset, ClusterSpec};
use quartz::linalg::Matrix;
use quartz::models::init_params;
use quartz::optim::BaseOptimizer;
use quartz::runtime::literal::{
    literal_to_matrix, matrix_to_literal, vec_f32_to_literal, vec_i32_to_literal,
};
use quartz::runtime::Runtime;
use quartz::shampoo::{Shampoo, ShampooConfig, ShampooVariant};
use quartz::train::OptimizerStack;
use quartz::util::bench::{black_box, Bencher};
use quartz::util::rng::Rng;

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("SKIP bench_tables: artifacts not built (run `make artifacts`)");
        return;
    }
    let rt = Runtime::open(&dir).expect("runtime");
    let mut b = Bencher::new();

    // Tab 3/4/5 unit: one amortized train step of the ResNet analog.
    let model = rt.manifest.models["res_mlp_c32"].clone();
    let spec =
        ClusterSpec { classes: 32, dim: 64, train: 512, test: 64, seed: 1, ..Default::default() };
    let (tr, _) = ClusterDataset::generate(&spec);
    let mut rng = Rng::new(5);

    for (label, variant) in [
        ("base", None),
        ("32bit", Some(ShampooVariant::Full32)),
        ("vq4", Some(ShampooVariant::Vq4)),
        ("cq4_ef", Some(ShampooVariant::Cq4 { error_feedback: true })),
    ] {
        let mut params = init_params(&model, 0);
        let mut opt = match variant {
            None => {
                let mut o = BaseOptimizer::sgdm(0.05, 0.9, 5e-4);
                o.init(params.len());
                OptimizerStack::base(o)
            }
            Some(v) => {
                // Paper-ratio intervals (T1=10, T2=50) so the bench includes
                // the amortized gram/root refresh cost.
                let cfg = ShampooConfig {
                    variant: v,
                    t1: 10,
                    t2: 50,
                    max_order: 96,
                    ..Default::default()
                };
                OptimizerStack::shampoo(Shampoo::new(
                    BaseOptimizer::sgdm(0.05, 0.9, 5e-4),
                    cfg,
                    &model.shapes(),
                ))
            }
        };

        let fwd = format!("{}.fwd_bwd", model.name);
        let batch = model.batch;
        let mut k = 1u64;
        b.bench(&format!("tab3_step/res_mlp_c32/{label}"), || {
            let idx: Vec<usize> = (0..batch).map(|_| rng.below(tr.len())).collect();
            let (x, y) = tr.gather(&idx);
            let yi: Vec<i32> = y.iter().map(|&v| v as i32).collect();
            let mut inputs = Vec::with_capacity(params.len() + 2);
            for p in &params {
                inputs.push(matrix_to_literal(p).unwrap());
            }
            inputs.push(vec_f32_to_literal(&x, &[batch, 64]).unwrap());
            inputs.push(vec_i32_to_literal(&yi, &[batch]).unwrap());
            let out = rt.execute(&fwd, &inputs).unwrap();
            let grads: Vec<Matrix> = out[1..]
                .iter()
                .zip(params.iter())
                .map(|(l, p)| literal_to_matrix(l, p.rows(), p.cols()).unwrap())
                .collect();
            opt.step(&mut params, &grads, k, 1.0);
            k += 1;
            black_box(&params);
        });
    }

    // Tab 6 unit: one LM train step (base vs CQ+EF).
    let model = rt.manifest.models["lm_s"].clone();
    let (batch, seq) = (model.batch, model.meta_usize("seq").unwrap());
    for (label, shampoo) in [("base", false), ("cq4_ef", true)] {
        let mut params = init_params(&model, 0);
        let mut opt = if shampoo {
            let cfg = ShampooConfig {
                variant: ShampooVariant::Cq4 { error_feedback: true },
                t1: 10,
                t2: 50,
                max_order: 96,
                ..Default::default()
            };
            OptimizerStack::shampoo(Shampoo::new(
                BaseOptimizer::adamw(3e-3, 0.9, 0.999, 1e-8, 0.0),
                cfg,
                &model.shapes(),
            ))
        } else {
            let mut o = BaseOptimizer::adamw(3e-3, 0.9, 0.999, 1e-8, 0.0);
            o.init(params.len());
            OptimizerStack::base(o)
        };
        let mut k = 1u64;
        b.bench(&format!("tab6_step/lm_s/{label}"), || {
            let x: Vec<i32> = (0..batch * seq).map(|_| rng.below(64) as i32).collect();
            let mut inputs = Vec::with_capacity(params.len() + 2);
            for p in &params {
                inputs.push(matrix_to_literal(p).unwrap());
            }
            inputs.push(vec_i32_to_literal(&x, &[batch, seq]).unwrap());
            inputs.push(vec_i32_to_literal(&x, &[batch, seq]).unwrap());
            let out = rt.execute("lm_s.fwd_bwd", &inputs).unwrap();
            let grads: Vec<Matrix> = out[1..]
                .iter()
                .zip(params.iter())
                .map(|(l, p)| literal_to_matrix(l, p.rows(), p.cols()).unwrap())
                .collect();
            opt.step(&mut params, &grads, k, 1.0);
            k += 1;
            black_box(&params);
        });
    }

    // Tab 1/9 unit: one NRE/AE evaluation (spectral analysis cost).
    let mut rng2 = Rng::new(6);
    let a = quartz::analysis::synthetic_pd(64, 1e-3, 1e3, &mut rng2);
    let q = quartz::quant::BlockQuantizer::new(quartz::quant::QuantConfig {
        min_quant_elems: 0,
        ..Default::default()
    });
    b.bench("tab1_unit/nre_ae_vq/64", || {
        let ga = quartz::analysis::vq_roundtrip(&a, &q);
        black_box(quartz::analysis::nre_ae(&a, &ga));
    });
    b.bench("tab1_unit/nre_ae_cq/64", || {
        let ga = quartz::analysis::cq_roundtrip(&a, 1e-6, &q);
        black_box(quartz::analysis::nre_ae(&a, &ga));
    });
}

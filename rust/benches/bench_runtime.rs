//! PJRT request-path benchmarks: end-to-end train-step latency through the
//! AOT artifacts (fwd/bwd execution + literal marshalling) — the L3 hot
//! loop the paper's wall-clock columns measure.
//!
//! Requires `make artifacts`; prints SKIP rows otherwise.

use quartz::linalg::Matrix;
use quartz::models::init_params;
use quartz::runtime::literal::{matrix_to_literal, vec_f32_to_literal, vec_i32_to_literal};
use quartz::runtime::Runtime;
use quartz::util::bench::{black_box, Bencher};
use quartz::util::rng::Rng;

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("SKIP bench_runtime: artifacts not built (run `make artifacts`)");
        return;
    }
    let rt = Runtime::open(&dir).expect("runtime");
    let mut b = Bencher::new();
    let mut rng = Rng::new(4);

    // Literal marshalling (per-step overhead).
    let m = Matrix::randn(128, 128, 1.0, &mut rng);
    b.bench_with_units("literal_from_matrix/128x128", Some(((128 * 128 * 4) as f64, "B")), || {
        black_box(matrix_to_literal(&m).unwrap());
    });

    // Kernel artifact latency (Pallas quant roundtrip through PJRT).
    let lit = matrix_to_literal(&m).unwrap();
    b.bench("pjrt_exec/kernel.quant_roundtrip", || {
        black_box(rt.execute("kernel.quant_roundtrip", std::slice::from_ref(&lit)).unwrap());
    });

    // Classifier fwd_bwd step latency.
    for model_name in ["mlp_vgg_c32", "res_mlp_c32", "vit_lite_c32"] {
        let model = rt.manifest.models[model_name].clone();
        let params = init_params(&model, 0);
        let batch = model.batch;
        let dim = model.meta_usize("dim").unwrap();
        let x: Vec<f32> = (0..batch * dim).map(|_| rng.normal_f32(1.0)).collect();
        let y: Vec<i32> = (0..batch).map(|_| rng.below(8) as i32).collect();
        let mut inputs = Vec::new();
        for p in &params {
            inputs.push(matrix_to_literal(p).unwrap());
        }
        inputs.push(vec_f32_to_literal(&x, &[batch, dim]).unwrap());
        inputs.push(vec_i32_to_literal(&y, &[batch]).unwrap());
        let name = format!("{model_name}.fwd_bwd");
        rt.execute(&name, &inputs).unwrap(); // warm compile
        b.bench(&format!("pjrt_fwd_bwd/{model_name}"), || {
            black_box(rt.execute(&name, &inputs).unwrap());
        });
    }

    // LM fwd_bwd step latency.
    let model = rt.manifest.models["lm_m"].clone();
    let params = init_params(&model, 0);
    let (batch, seq) = (model.batch, model.meta_usize("seq").unwrap());
    let x: Vec<i32> = (0..batch * seq).map(|_| rng.below(64) as i32).collect();
    let mut inputs = Vec::new();
    for p in &params {
        inputs.push(matrix_to_literal(p).unwrap());
    }
    inputs.push(vec_i32_to_literal(&x, &[batch, seq]).unwrap());
    inputs.push(vec_i32_to_literal(&x, &[batch, seq]).unwrap());
    rt.execute("lm_m.fwd_bwd", &inputs).unwrap();
    b.bench("pjrt_fwd_bwd/lm_m", || {
        black_box(rt.execute("lm_m.fwd_bwd", &inputs).unwrap());
    });
}

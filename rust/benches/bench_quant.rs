//! Quantization hot-path benchmarks (L3 §Perf): fused block-wise quantize /
//! dequantize (boundary-table encode, streamed nibble packing, row-block
//! parallelism), the buffer-reusing `quantize_into`, off-diagonal variants,
//! and the fused Fig. 2 joint triangular store at preconditioner orders up
//! to 2048.
//!
//! Run: `cargo bench --bench bench_quant` (QUARTZ_BENCH_QUICK=1 for smoke).

use quartz::linalg::Matrix;
use quartz::quant::{
    dequantize_offdiag, quantize_offdiag, BlockQuantizer, QuantConfig, TriJointStore,
};
use quartz::util::bench::{black_box, Bencher};
use quartz::util::rng::Rng;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::new(1);
    let quantizer = BlockQuantizer::new(QuantConfig { min_quant_elems: 0, ..Default::default() });

    // Order 2048 stays out of quick mode (same gate as bench_codecs) so the
    // CI smoke keeps its sub-minute budget; full runs cover it.
    let quick = std::env::var("QUARTZ_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let orders: &[usize] =
        if quick { &[64, 128, 256, 512, 1024] } else { &[64, 128, 256, 512, 1024, 2048] };
    let tri_orders: &[usize] = if quick { &[256, 512, 1024] } else { &[256, 512, 1024, 2048] };

    for &n in orders {
        let x = Matrix::randn(n, n, 1.0, &mut rng);
        let bytes = (n * n * 4) as f64;
        b.bench_with_units(&format!("quantize/{n}x{n}"), Some((bytes, "B")), || {
            black_box(quantizer.quantize(&x));
        });
        // Buffer-reusing variant — the codec store hot path (no alloc).
        let mut shell = quantizer.quantize(&x);
        b.bench_with_units(&format!("quantize_into/{n}x{n}"), Some((bytes, "B")), || {
            quantizer.quantize_into(&x, &mut shell);
            black_box(&shell);
        });
        let q = quantizer.quantize(&x);
        let mut out = Matrix::zeros(n, n);
        b.bench_with_units(&format!("dequantize/{n}x{n}"), Some((bytes, "B")), || {
            quantizer.dequantize_into(&q, &mut out);
            black_box(&out);
        });
    }

    // Off-diagonal quantization (the Shampoo store path).
    let n = 256;
    let x = Matrix::randn(n, n, 1.0, &mut rng);
    b.bench(&format!("quantize_offdiag/{n}x{n}"), || {
        black_box(quantize_offdiag(&x, &quantizer));
    });
    let s = quantize_offdiag(&x, &quantizer);
    b.bench(&format!("dequantize_offdiag/{n}x{n}"), || {
        black_box(dequantize_offdiag(&s, &quantizer));
    });

    // Fig. 2 joint triangular store (CQ+EF persistence), fused paths at the
    // paper-relevant preconditioner orders.
    for &n in tri_orders {
        let c = Matrix::from_fn(n, n, |i, j| {
            if i >= j {
                1.0 + (i * j % 7) as f32 * 0.1
            } else {
                0.0
            }
        });
        let e = Matrix::from_fn(n, n, |i, j| if i > j { 0.01 } else { 0.0 });
        b.bench(&format!("tri_store_pack/{n}x{n}"), || {
            black_box(TriJointStore::store(&c, &e, &quantizer));
        });
        let mut store = TriJointStore::store(&c, &e, &quantizer);
        b.bench(&format!("tri_store_pack_into/{n}x{n}"), || {
            store.store_into(&c, &e, &quantizer);
            black_box(&store);
        });
        b.bench(&format!("tri_store_load/{n}x{n}"), || {
            black_box(store.load(&quantizer));
        });
        let (mut lc, mut le) = store.load(&quantizer);
        b.bench(&format!("tri_store_load_into/{n}x{n}"), || {
            store.load_into(&quantizer, &mut lc, &mut le);
            black_box((&lc, &le));
        });
    }

    // Codebook encode alone (the inner loop): boundary-table vs the scalar
    // midpoint reference it replaced.
    let cb = quantizer.codebook().clone();
    let vals: Vec<f32> = (0..4096).map(|i| -1.0 + 2.0 * (i as f32) / 4095.0).collect();
    b.bench_with_units("codebook_encode/4096", Some((4096.0, "elem")), || {
        let mut acc = 0u32;
        for &v in &vals {
            acc = acc.wrapping_add(cb.encode(v) as u32);
        }
        black_box(acc);
    });
    b.bench_with_units("codebook_encode_scalar/4096", Some((4096.0, "elem")), || {
        let mut acc = 0u32;
        for &v in &vals {
            acc = acc.wrapping_add(cb.encode_scalar(v) as u32);
        }
        black_box(acc);
    });
}

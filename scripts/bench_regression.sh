#!/usr/bin/env bash
# Bench regression comparison (ROADMAP item "wire regression comparison"):
# diff two BENCH_quartz.json records and flag every benchmark whose mean
# time regressed by more than THRESHOLD_PCT percent (default 20 — i.e. a
# >20% throughput drop on that kernel).
#
# Usage: scripts/bench_regression.sh BASELINE.json CURRENT.json [threshold_pct]
#
# Advisory by default: regressions are printed (and surfaced as GitHub
# warning annotations when running in Actions) but the exit code stays 0,
# because the CI smoke runs on shared runners whose noise floor is well
# above a rigorous measurement. Set REGRESSION_STRICT=1 to turn flagged
# regressions into a non-zero exit. STRICT_FILTER (an awk ERE, default
# '.*') narrows which benchmark names can fail the run: regressions
# outside the filter are still printed and annotated, but stay advisory.
# CI measures the runner's actual noise floor first (scripts/bench_noise.sh)
# and only arms the strict gate for kernels whose floor supports it — see
# docs/PERFORMANCE.md, "Reading the bench trajectory".
#
# Records are the JSONL objects util::bench emits, assembled by
# scripts/harvest_bench.sh — the parser below relies on that exact shape
# ("name":"...","mean_ns":N), not on a general JSON grammar. Besides the
# kernel/codec records this covers the end-to-end optimizer records
# (step_mix/<refresh-policy> and step_*/<variant> from bench_shampoo), so
# refresh-scheduler and step-path slowdowns surface through the same
# advisory CI gate.
set -euo pipefail

BASE="${1:?usage: bench_regression.sh BASELINE.json CURRENT.json [threshold_pct]}"
CUR="${2:?usage: bench_regression.sh BASELINE.json CURRENT.json [threshold_pct]}"
THRESH="${3:-20}"

if [[ ! -f "$BASE" ]]; then
  echo "bench_regression: no baseline at $BASE — first run, nothing to compare"
  exit 0
fi
if [[ ! -f "$CUR" ]]; then
  echo "bench_regression: current record $CUR missing" >&2
  exit 1
fi

extract() {
  grep -o '"name":"[^"]*","mean_ns":[0-9.]*' "$1" \
    | sed 's/"name":"\([^"]*\)","mean_ns":\([0-9.]*\)/\1 \2/' \
    | sort -k1,1
}

join <(extract "$BASE") <(extract "$CUR") | awk -v thresh="$THRESH" '
  BEGIN {
    regressions = 0; hard = 0; improvements = 0; compared = 0;
    strict = (ENVIRON["REGRESSION_STRICT"] == "1");
    filter = ENVIRON["STRICT_FILTER"];
    if (filter == "") filter = ".*";
    printf "%-52s %12s %12s %9s\n", "benchmark", "base ns", "current ns", "delta";
  }
  {
    name = $1; base = $2 + 0; cur = $3 + 0;
    if (base <= 0) next;
    compared++;
    pct = (cur / base - 1) * 100;
    flag = "";
    if (pct > thresh) {
      regressions++;
      flag = "  << REGRESSION";
      if (name ~ filter) { hard++; if (strict) flag = flag " (gated)"; }
    }
    else if (pct < -thresh) { flag = "  (faster)"; improvements++; }
    if (flag != "" )
      printf "%-52s %12.0f %12.0f %+8.1f%%%s\n", name, base, cur, pct, flag;
    if (pct > thresh && ENVIRON["GITHUB_ACTIONS"] == "true")
      printf "::warning::bench regression: %s %.0fns -> %.0fns (%+.1f%%)\n", name, base, cur, pct;
  }
  END {
    printf "compared %d benchmarks: %d regressed >%s%%, %d sped up >%s%%\n",
           compared, regressions, thresh, improvements, thresh;
    if (compared == 0) print "bench_regression: WARNING — no overlapping benchmark names";
    exit (ENVIRON["REGRESSION_STRICT"] == "1" && hard > 0) ? 1 : 0;
  }
'

#!/usr/bin/env bash
# Chaos smoke for the numerical-health guard engine (CI gate): run a tiny
# synthetic queue with deterministic fault injection live — NaN/Inf
# gradient spikes, forced factorization failures, checkpoint bit flips —
# SIGKILL the process mid-run, `quartz resume` the queue directory, and
# assert the final metrics are finite AND byte-identical to an
# uninterrupted control run of the same spec. The cq-ef run drives the
# sharded async-refresh engine (async_refresh = true), so the SIGKILL
# regularly lands with root refreshes in flight — checkpoints drain the
# engine, and the resumed run must still replay the control bit-for-bit. The fault plan is a pure
# function of (seed, step), so the resumed tail replays the exact same
# corruption schedule; screening keeps every run finite; the flipped
# checkpoints are rejected by CRC and resume falls back to intact ones.
# Health counters must appear in the metrics stream and `quartz health`
# must render them.
#
# Usage: scripts/chaos_smoke.sh [workdir]
#
# QUARTZ_BIN overrides the binary (default rust/target/release/quartz,
# built on demand). The kill is timing-based: if the queue finishes
# before the signal lands, the comparison degenerates to
# cached-replay-vs-control, which still must match.
set -euo pipefail

BIN="${QUARTZ_BIN:-rust/target/release/quartz}"
WORK="${1:-$(mktemp -d -t quartz-chaos-smoke-XXXXXX)}"
PACE_MS="${PACE_MS:-50}"
KILL_AFTER_SECS="${KILL_AFTER_SECS:-2}"

if [[ ! -x "$BIN" ]]; then
  echo "chaos_smoke: building $BIN"
  (cd rust && cargo build --release --quiet)
fi

mkdir -p "$WORK"
SPEC="$WORK/queue.toml"
# Faults are live for the first half of each run: gradient spikes every
# 13/29 steps, forced root failures every 7th step on about half the
# units, and a bit flip on every second checkpoint written.
cat > "$SPEC" <<EOF
name = "chaos-smoke"
steps = 120
workers = 1
checkpoint_every = 10
keep_checkpoints = 3

[workload]
kind = "synthetic"
shapes = [16, 8, 8, 8, 4, 1]
noise = 0.05
pace_ms = $PACE_MS

[faults]
seed = 7
nan_grad_every = 13
inf_grad_every = 29
force_fail_every = 7
fail_one_in = 2
ckpt_flip_every = 20
until_step = 60

[[runs]]
model = "syn"
base = "sgdm"
shampoo = "cq-ef"
async_refresh = true
async_shards = 2
max_async_staleness = 2

[[runs]]
model = "syn"
base = "sgdm"
EOF

KILLED="$WORK/killed"
CONTROL="$WORK/control"

echo "chaos_smoke: launching faulted queue, SIGKILL in ${KILL_AFTER_SECS}s"
"$BIN" queue "$SPEC" --out "$KILLED" > "$WORK/killed-attempt.log" 2>&1 &
PID=$!
sleep "$KILL_AFTER_SECS"
if kill -9 "$PID" 2>/dev/null; then
  wait "$PID" 2>/dev/null || true
  echo "chaos_smoke: killed pid $PID mid-queue"
else
  echo "chaos_smoke: WARNING — queue finished before the kill landed" >&2
fi

echo "chaos_smoke: resuming $KILLED"
"$BIN" resume "$KILLED" > "$WORK/resume.log" 2>&1 \
  || { cat "$WORK/resume.log"; exit 1; }

echo "chaos_smoke: uninterrupted control run"
"$BIN" queue "$SPEC" --out "$CONTROL" > "$WORK/control.log" 2>&1 \
  || { cat "$WORK/control.log"; exit 1; }

# Last run_end per run id -> "id<TAB>final_metric", sorted for a stable
# diff (run ids contain spaces, hence tabs).
finals() {
  grep '"run_end"' "$1/metrics.jsonl" | while IFS= read -r line; do
    id=$(printf '%s' "$line" | grep -o '"id":"[^"]*"' | head -n1)
    fm=$(printf '%s' "$line" | grep -o '"final_metric":[^,}]*' | head -n1)
    printf '%s\t%s\n' "$id" "$fm"
  done | awk -F'\t' '{last[$1] = $2} END {for (k in last) print k "\t" last[k]}' | sort
}

finals "$KILLED" > "$WORK/killed.finals"
finals "$CONTROL" > "$WORK/control.finals"

echo "--- resumed finals ---"
cat "$WORK/killed.finals"
echo "--- control finals ---"
cat "$WORK/control.finals"

RUNS=$(wc -l < "$WORK/control.finals")
if [[ "$RUNS" -ne 2 ]]; then
  echo "chaos_smoke: FAIL — control produced $RUNS run_end record(s), expected 2" >&2
  exit 1
fi
# Screening must keep every faulted run finite.
if grep -qiE 'nan|inf|null' "$WORK/control.finals"; then
  echo "chaos_smoke: FAIL — non-finite final metric under fault injection" >&2
  exit 1
fi
if ! diff -u "$WORK/control.finals" "$WORK/killed.finals"; then
  echo "chaos_smoke: FAIL — resumed faulted queue diverges from control" >&2
  exit 1
fi

# The guard engine's counters must be streamed with each run_end…
if ! grep '"run_end"' "$CONTROL/metrics.jsonl" | grep -q '"grads_screened"'; then
  echo "chaos_smoke: FAIL — no health counters in the metrics stream" >&2
  exit 1
fi
# …with screening actually having fired (the plan schedules NaN steps).
if ! grep '"run_end"' "$CONTROL/metrics.jsonl" | grep -qE '"grads_screened":[1-9]'; then
  echo "chaos_smoke: FAIL — fault plan active but zero gradients screened" >&2
  exit 1
fi

echo "chaos_smoke: health report for the resumed queue"
"$BIN" health "$KILLED" | tee "$WORK/health.log"
if ! grep -q 'totals:' "$WORK/health.log"; then
  echo "chaos_smoke: FAIL — 'quartz health' produced no totals line" >&2
  exit 1
fi

echo "chaos_smoke: OK — faulted queue stayed finite, resumed bit-identically, and reported health"

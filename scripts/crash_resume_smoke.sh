#!/usr/bin/env bash
# Crash/resume smoke for the persistence layer (CI gate): launch a tiny
# synthetic queue, SIGKILL the process mid-run, `quartz resume` the queue
# directory, and assert the final metrics are byte-identical to an
# uninterrupted control run of the same spec. This exercises the whole
# contract end to end — periodic checkpoint writes, atomic temp+rename
# (a kill can never leave a half-written .ckpt visible), the JSONL
# metrics stream surviving a torn tail line, and bit-identical resume.
#
# Usage: scripts/crash_resume_smoke.sh [workdir]
#
# QUARTZ_BIN overrides the binary (default rust/target/release/quartz,
# built on demand). The kill is timing-based: if the queue finishes
# before the signal lands (very fast runner), the script warns and the
# comparison degenerates to cached-replay-vs-control, which still must
# match — the hard gate is the metric equality, not the kill landing.
set -euo pipefail

BIN="${QUARTZ_BIN:-rust/target/release/quartz}"
WORK="${1:-$(mktemp -d -t quartz-crash-smoke-XXXXXX)}"
PACE_MS="${PACE_MS:-50}"
KILL_AFTER_SECS="${KILL_AFTER_SECS:-2}"

if [[ ! -x "$BIN" ]]; then
  echo "crash_resume_smoke: building $BIN"
  (cd rust && cargo build --release --quiet)
fi

mkdir -p "$WORK"
SPEC="$WORK/queue.toml"
# ~120 steps x PACE_MS per run keeps the first run in flight for several
# seconds, so the SIGKILL lands mid-run with checkpoints already on disk.
cat > "$SPEC" <<EOF
name = "crash-smoke"
steps = 120
workers = 1
checkpoint_every = 10

[workload]
kind = "synthetic"
shapes = [16, 8, 8, 8, 4, 1]
noise = 0.05
pace_ms = $PACE_MS

[[runs]]
model = "syn"
base = "sgdm"
shampoo = "cq-ef"

[[runs]]
model = "syn"
base = "sgdm"
EOF

KILLED="$WORK/killed"
CONTROL="$WORK/control"

echo "crash_resume_smoke: launching queue, SIGKILL in ${KILL_AFTER_SECS}s"
"$BIN" queue "$SPEC" --out "$KILLED" > "$WORK/killed-attempt.log" 2>&1 &
PID=$!
sleep "$KILL_AFTER_SECS"
if kill -9 "$PID" 2>/dev/null; then
  wait "$PID" 2>/dev/null || true
  echo "crash_resume_smoke: killed pid $PID mid-queue"
else
  echo "crash_resume_smoke: WARNING — queue finished before the kill landed" >&2
fi

CKPTS=$( (find "$KILLED/runs" -name '*.ckpt' 2>/dev/null || true) | wc -l)
echo "crash_resume_smoke: $CKPTS checkpoint(s) on disk at kill time"

echo "crash_resume_smoke: resuming $KILLED"
"$BIN" resume "$KILLED" > "$WORK/resume.log" 2>&1 \
  || { cat "$WORK/resume.log"; exit 1; }

echo "crash_resume_smoke: uninterrupted control run"
"$BIN" queue "$SPEC" --out "$CONTROL" > "$WORK/control.log" 2>&1 \
  || { cat "$WORK/control.log"; exit 1; }

# Last run_end per run id -> "id<TAB>final_metric", sorted for a stable
# diff. Tab-separated: run ids ("syn/SGDM + cq-ef Shampoo") contain spaces.
finals() {
  grep '"run_end"' "$1/metrics.jsonl" | while IFS= read -r line; do
    id=$(printf '%s' "$line" | grep -o '"id":"[^"]*"' | head -n1)
    fm=$(printf '%s' "$line" | grep -o '"final_metric":[^,}]*' | head -n1)
    printf '%s\t%s\n' "$id" "$fm"
  done | awk -F'\t' '{last[$1] = $2} END {for (k in last) print k "\t" last[k]}' | sort
}

finals "$KILLED" > "$WORK/killed.finals"
finals "$CONTROL" > "$WORK/control.finals"

echo "--- resumed finals ---"
cat "$WORK/killed.finals"
echo "--- control finals ---"
cat "$WORK/control.finals"

RUNS=$(wc -l < "$WORK/control.finals")
if [[ "$RUNS" -ne 2 ]]; then
  echo "crash_resume_smoke: FAIL — control produced $RUNS run_end record(s), expected 2" >&2
  exit 1
fi
if ! diff -u "$WORK/control.finals" "$WORK/killed.finals"; then
  echo "crash_resume_smoke: FAIL — resumed metrics diverge from uninterrupted control" >&2
  exit 1
fi

echo "crash_resume_smoke: OK — resumed queue matches uninterrupted control exactly"

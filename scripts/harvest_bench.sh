#!/usr/bin/env bash
# Bench smoke + harvest: run the in-tree bench suite in quick mode and
# assemble the per-report JSONL records (emitted by util::bench when
# QUARTZ_BENCH_JSON is set) into a single BENCH_quartz.json.
#
# `cargo bench` runs every [[bench]] target, including bench_codecs — the
# per-codec quantize/dequantize throughput at orders 512/1024 whose records
# (codec_store/*, codec_load/*) seed the codec regression trajectory — and
# bench_shampoo's end-to-end step records: step_precondition_only/*,
# step_with_gram_update/*, step_full_refresh/* per variant, plus the
# refresh-scheduler step benches at the transformer-ish layer mix
# (step_mix/every-n, step_mix/staggered, step_mix/staleness), which feed
# scripts/bench_regression.sh so a policy-level slowdown is flagged like
# any kernel regression. The async-refresh engine records
# (step_mix_async/off, step_mix_async/2, step_mix_async/4) sit alongside
# them — off vs sharded overlap at the same mix, the refresh-spike
# evidence for the bounded-staleness engine.
#
# Usage: scripts/harvest_bench.sh [output.json]
#
# The quick mode (QUARTZ_BENCH_QUICK=1) shrinks warmup/measure windows so the
# whole suite finishes in well under a minute — this is a smoke run seeding
# the perf trajectory, not a statistically rigorous measurement.
set -euo pipefail

cd "$(dirname "$0")/.."
OUT="${1:-BENCH_quartz.json}"
JSONL="$(mktemp)"
trap 'rm -f "$JSONL"' EXIT

export QUARTZ_BENCH_QUICK=1
export QUARTZ_BENCH_JSON="$JSONL"

(cd rust && cargo bench)

{
  printf '{"suite":"quartz","mode":"quick","results":['
  # Join the JSONL records with commas (empty file -> empty array).
  paste -sd, "$JSONL"
  printf ']}\n'
} > "$OUT"

COUNT="$(wc -l < "$JSONL" | tr -d ' ')"
echo "harvested $COUNT bench records into $OUT"
# A smoke run with zero records means the benches did not actually execute.
test "$COUNT" -gt 0

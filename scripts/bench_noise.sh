#!/usr/bin/env bash
# Measure the bench smoke suite's run-over-run noise floor.
#
# Runs scripts/harvest_bench.sh TWICE back-to-back on the same machine and
# same code, joins the two records by benchmark name, and summarizes the
# absolute per-benchmark mean-time deltas. Since nothing changed between
# the runs, every delta is pure measurement noise — the p95 of their
# absolute values is the floor below which a regression gate cannot
# distinguish signal from scheduler jitter.
#
# Usage: scripts/bench_noise.sh [output.json]   (default .bench-noise.json)
#
# Output shape (consumed by the CI bench-smoke job to decide whether the
# >THRESHOLD_PCT gate in bench_regression.sh may run strict):
#   {"suite":"quartz","mode":"quick","compared":N,
#    "noise_floor_pct":P95_ABS_DELTA,"max_pct":MAX_ABS_DELTA}
#
# The parser mirrors bench_regression.sh: it keys on the exact
# ("name":"...","mean_ns":N) shape util::bench emits, not a general JSON
# grammar. See docs/PERFORMANCE.md, "Reading the bench trajectory".
set -euo pipefail

cd "$(dirname "$0")/.."
OUT="${1:-.bench-noise.json}"
RUN_A="$(mktemp)"
RUN_B="$(mktemp)"
trap 'rm -f "$RUN_A" "$RUN_B"' EXIT

echo "bench_noise: first smoke run"
scripts/harvest_bench.sh "$RUN_A" > /dev/null
echo "bench_noise: second smoke run"
scripts/harvest_bench.sh "$RUN_B" > /dev/null

extract() {
  grep -o '"name":"[^"]*","mean_ns":[0-9.]*' "$1" \
    | sed 's/"name":"\([^"]*\)","mean_ns":\([0-9.]*\)/\1 \2/' \
    | sort -k1,1
}

# Absolute percent deltas, sorted ascending (so p95/max are positional).
DELTAS="$(join <(extract "$RUN_A") <(extract "$RUN_B") \
  | awk '{ a = $2 + 0; b = $3 + 0;
           if (a > 0) { d = (b / a - 1) * 100; if (d < 0) d = -d; print d } }' \
  | sort -g)"

if [[ -z "$DELTAS" ]]; then
  echo "bench_noise: no overlapping benchmark records between the two runs" >&2
  exit 1
fi

read -r COMPARED FLOOR MAX <<EOF
$(printf '%s\n' "$DELTAS" | awk '
  { v[n++] = $1 + 0 }
  END {
    i = int(0.95 * (n - 1));
    printf "%d %.3f %.3f\n", n, v[i], v[n - 1];
  }')
EOF

printf '{"suite":"quartz","mode":"quick","compared":%s,"noise_floor_pct":%s,"max_pct":%s}\n' \
  "$COMPARED" "$FLOOR" "$MAX" > "$OUT"
echo "bench_noise: $COMPARED benchmarks, p95 |delta| ${FLOOR}%, max ${MAX}% -> $OUT"

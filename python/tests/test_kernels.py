"""L1 kernel correctness: Pallas vs pure-jnp oracle (ref.py).

hypothesis sweeps shapes, block sizes, and value distributions; every
property asserts allclose (or exact equality for code paths that must be
bit-identical, like the nearest-level encode).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import precond, quant, ref

LEVELS4 = jnp.asarray(ref.linear2_levels(4))


def rand_matrix(draw, max_side=96, scale_pow=2):
    rows = draw(st.integers(1, max_side))
    cols = draw(st.integers(1, max_side))
    seed = draw(st.integers(0, 2**31 - 1))
    scale = 10.0 ** draw(st.integers(-scale_pow, scale_pow))
    rng = np.random.RandomState(seed)
    return (rng.randn(rows, cols) * scale).astype(np.float32)


matrices = st.builds(lambda: None)  # placeholder; use @st.composite below


@st.composite
def matrix_strategy(draw, max_side=96):
    return rand_matrix(draw, max_side=max_side)


@st.composite
def matrix_and_block(draw):
    x = rand_matrix(draw, max_side=96)
    block = draw(st.sampled_from([4, 8, 16, 32, 64]))
    return x, block


class TestQuantKernel:
    @settings(max_examples=30, deadline=None)
    @given(matrix_and_block())
    def test_roundtrip_matches_ref(self, xb):
        x, block = xb
        got = quant.quantize_roundtrip(jnp.asarray(x), block=block)
        want = ref.roundtrip_ref(jnp.asarray(x), block, LEVELS4)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)

    @settings(max_examples=20, deadline=None)
    @given(matrix_and_block())
    def test_codes_and_scales_match_ref(self, xb):
        x, block = xb
        codes, scales = quant.blockwise_quantize(jnp.asarray(x), block=block)
        rcodes, rscales = ref.blockwise_quantize_ref(jnp.asarray(x), block, LEVELS4)
        np.testing.assert_array_equal(np.asarray(codes), np.asarray(rcodes))
        np.testing.assert_allclose(np.asarray(scales), np.asarray(rscales))

    @settings(max_examples=20, deadline=None)
    @given(matrix_and_block())
    def test_error_bound_prop_b1(self, xb):
        """Proposition B.1: per-block error ≤ scale · max half-gap."""
        x, block = xb
        back = np.asarray(quant.quantize_roundtrip(jnp.asarray(x), block=block))
        lv = np.asarray(LEVELS4)
        half_gap = np.max(lv[1:] - lv[:-1]) / 2
        m, n = x.shape
        for i in range(m):
            for j in range(n):
                bi, bj = i // block, j // block
                blk = x[bi * block:(bi + 1) * block, bj * block:(bj + 1) * block]
                scale = np.max(np.abs(blk))
                assert abs(back[i, j] - x[i, j]) <= scale * half_gap + 1e-6

    def test_zero_matrix(self):
        x = jnp.zeros((32, 32))
        got = quant.quantize_roundtrip(x, block=16)
        np.testing.assert_array_equal(np.asarray(got), 0.0)

    def test_exact_levels_roundtrip(self):
        lv = np.asarray(LEVELS4)
        x = (3.7 * lv[np.arange(64) % 16]).reshape(8, 8).astype(np.float32)
        got = quant.quantize_roundtrip(jnp.asarray(x), block=8)
        np.testing.assert_allclose(np.asarray(got), x, atol=1e-6)

    def test_outlier_isolation(self):
        """Block-wise normalization confines outliers to their block."""
        rng = np.random.RandomState(0)
        x = rng.randn(32, 32).astype(np.float32)
        x[0, 0] = 1e6
        back = np.asarray(quant.quantize_roundtrip(jnp.asarray(x), block=16))
        err_far = np.max(np.abs(back[16:, 16:] - x[16:, 16:]))
        assert err_far < 0.5


class TestPrecondKernel:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 80), st.integers(1, 80), st.integers(1, 80),
           st.integers(0, 2**31 - 1))
    def test_matmul_matches_ref(self, m, k, n, seed):
        rng = np.random.RandomState(seed)
        a = rng.randn(m, k).astype(np.float32)
        b = rng.randn(k, n).astype(np.float32)
        got = precond.pallas_matmul(jnp.asarray(a), jnp.asarray(b))
        np.testing.assert_allclose(np.asarray(got), a @ b, rtol=1e-4, atol=1e-3)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(2, 48), st.integers(2, 48), st.integers(0, 2**31 - 1))
    def test_precond_apply_matches_ref(self, m, n, seed):
        rng = np.random.RandomState(seed)
        l = rng.randn(m, m).astype(np.float32)
        g = rng.randn(m, n).astype(np.float32)
        r = rng.randn(n, n).astype(np.float32)
        got = precond.precond_apply(jnp.asarray(l), jnp.asarray(g), jnp.asarray(r))
        want = ref.precond_apply_ref(l, g, r)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-2)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(2, 48), st.integers(2, 48), st.booleans(),
           st.floats(0.5, 0.99), st.integers(0, 2**31 - 1))
    def test_gram_ema_matches_ref(self, m, n, left, beta, seed):
        rng = np.random.RandomState(seed)
        g = rng.randn(m, n).astype(np.float32)
        dim = m if left else n
        prev = np.eye(dim, dtype=np.float32) * 0.3
        got = precond.gram_ema(jnp.asarray(prev), jnp.asarray(g),
                               jnp.float32(beta), left=left)
        want = ref.gram_ema_ref(prev, g, beta, left)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)

    def test_pmatmul_vjp(self):
        """Custom VJP = three matmuls through the same kernel."""
        rng = np.random.RandomState(1)
        a = rng.randn(24, 16).astype(np.float32)
        b = rng.randn(16, 8).astype(np.float32)

        def f(a, b):
            return jnp.sum(precond.pmatmul(a, b) ** 2)

        ga, gb = jax.grad(f, argnums=(0, 1))(jnp.asarray(a), jnp.asarray(b))
        c = a @ b
        np.testing.assert_allclose(np.asarray(ga), 2 * c @ b.T, rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(np.asarray(gb), a.T @ (2 * c), rtol=1e-3, atol=1e-3)


class TestLevels:
    def test_linear2_matches_eq4(self):
        lv = ref.linear2_levels(4)
        assert lv.shape == (16,)
        assert lv[7] == 0.0
        assert lv[0] == -1.0
        assert lv[15] == 1.0
        assert np.all(np.diff(lv) > 0), "strictly increasing"
        # Eq. (4) spot value: j=11 → (−1+22/15)²
        np.testing.assert_allclose(lv[11], (7.0 / 15.0) ** 2, rtol=1e-6)

    @pytest.mark.parametrize("bits", [2, 3, 4])
    def test_other_bit_widths(self, bits):
        lv = ref.linear2_levels(bits)
        assert lv.shape == (1 << bits,)
        assert np.all(np.diff(lv) > 0)

"""L2 — JAX model graphs (build-time only; never imported at runtime).

Scaled analogs of the paper's workloads (DESIGN.md §4), all dense algebra
routed through the L1 Pallas kernel (``pmatmul``) so the kernel lowers into
the fwd **and** bwd HLO of every artifact:

* ``mlp_*``    — deep MLP classifier (VGG-19 analog)
* ``res_*``    — residual MLP (ResNet-34/50 analog)
* ``vit_*``    — single/dual-block self-attention classifier (ViT/Swin analog)
* ``lm_*``     — decoder-only transformer LM (LLaMA analog, Tab. 6)

Every model exposes flat parameter lists (name, shape, init std) so the rust
coordinator can initialize identical buffers and drive training through the
AOT-compiled ``fwd_bwd`` graph: inputs ``(*params, x, y)``, outputs
``(loss, *grads)``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels.precond import pmatmul


# --------------------------------------------------------------------------
# Parameter plumbing
# --------------------------------------------------------------------------

@dataclass
class ParamSpec:
    name: str
    shape: tuple[int, int]
    std: float


@dataclass
class ModelDef:
    """A lowered-artifact definition the AOT driver iterates over."""

    name: str
    kind: str  # "classifier" | "lm"
    params: list[ParamSpec]
    # fwd_bwd(params_list, x, y) -> (loss, grads_list)
    loss_fn: Callable
    # eval_fn(params_list, x) -> logits  (classifier)
    # eval_fn(params_list, x, y) -> nll  (lm)
    eval_fn: Callable
    batch: int
    meta: dict = field(default_factory=dict)

    def input_specs(self):
        if self.kind == "classifier":
            dim = self.meta["dim"]
            return (
                jax.ShapeDtypeStruct((self.batch, dim), jnp.float32),
                jax.ShapeDtypeStruct((self.batch,), jnp.int32),
            )
        seq = self.meta["seq"]
        return (
            jax.ShapeDtypeStruct((self.batch, seq), jnp.int32),
            jax.ShapeDtypeStruct((self.batch, seq), jnp.int32),
        )

    def param_specs(self):
        return [jax.ShapeDtypeStruct(p.shape, jnp.float32) for p in self.params]


def _ce_loss(logits: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Mean cross-entropy, numerically stable (y integer labels)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - picked)


def _dense(h, w, b):
    """Dense layer on the Pallas matmul kernel; bias is a (1, n) matrix."""
    return pmatmul(h, w) + b


# --------------------------------------------------------------------------
# MLP classifier (VGG analog)
# --------------------------------------------------------------------------

def make_mlp(name: str, dim: int, hidden: list[int], classes: int, batch: int) -> ModelDef:
    params: list[ParamSpec] = []
    dims = [dim] + hidden + [classes]
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        params.append(ParamSpec(f"w{i}", (a, b), (2.0 / a) ** 0.5))
        params.append(ParamSpec(f"b{i}", (1, b), 0.0))

    n_layers = len(dims) - 1

    def forward(plist, x):
        h = x
        for i in range(n_layers):
            w, b = plist[2 * i], plist[2 * i + 1]
            h = _dense(h, w, b)
            if i + 1 < n_layers:
                h = jax.nn.relu(h)
        return h

    def loss_fn(plist, x, y):
        return _ce_loss(forward(plist, x), y)

    return ModelDef(
        name=name,
        kind="classifier",
        params=params,
        loss_fn=loss_fn,
        eval_fn=forward,
        batch=batch,
        meta={"dim": dim, "classes": classes},
    )


# --------------------------------------------------------------------------
# Residual MLP (ResNet analog)
# --------------------------------------------------------------------------

def make_resmlp(name: str, dim: int, width: int, blocks: int, classes: int,
                batch: int) -> ModelDef:
    params: list[ParamSpec] = [
        ParamSpec("stem_w", (dim, width), (2.0 / dim) ** 0.5),
        ParamSpec("stem_b", (1, width), 0.0),
    ]
    for i in range(blocks):
        params.append(ParamSpec(f"blk{i}_w1", (width, width), (2.0 / width) ** 0.5))
        params.append(ParamSpec(f"blk{i}_b1", (1, width), 0.0))
        params.append(ParamSpec(f"blk{i}_w2", (width, width), (2.0 / width) ** 0.5))
        params.append(ParamSpec(f"blk{i}_b2", (1, width), 0.0))
    params.append(ParamSpec("head_w", (width, classes), (1.0 / width) ** 0.5))
    params.append(ParamSpec("head_b", (1, classes), 0.0))

    def forward(plist, x):
        h = jax.nn.relu(_dense(x, plist[0], plist[1]))
        idx = 2
        for _ in range(blocks):
            w1, b1, w2, b2 = plist[idx], plist[idx + 1], plist[idx + 2], plist[idx + 3]
            idx += 4
            inner = jax.nn.relu(_dense(h, w1, b1))
            h = h + _dense(inner, w2, b2)
            h = jax.nn.relu(h)
        return _dense(h, plist[idx], plist[idx + 1])

    def loss_fn(plist, x, y):
        return _ce_loss(forward(plist, x), y)

    return ModelDef(
        name=name,
        kind="classifier",
        params=params,
        loss_fn=loss_fn,
        eval_fn=forward,
        batch=batch,
        meta={"dim": dim, "classes": classes},
    )


# --------------------------------------------------------------------------
# Attention building block (shared by ViT analog and the LM)
# --------------------------------------------------------------------------

def _attention(h, wq, wk, wv, wo, heads: int, causal: bool):
    """Multi-head self-attention over `h` [tokens, d] (single sequence) or
    [B*T, d] reshaped by the caller; operates on 3-D [B, T, d]."""
    bsz, t, d = h.shape
    dh = d // heads
    flat = h.reshape(bsz * t, d)
    q = pmatmul(flat, wq).reshape(bsz, t, heads, dh).transpose(0, 2, 1, 3)
    k = pmatmul(flat, wk).reshape(bsz, t, heads, dh).transpose(0, 2, 1, 3)
    v = pmatmul(flat, wv).reshape(bsz, t, heads, dh).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / (dh ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        scores = jnp.where(mask, scores, -1e30)
    att = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(bsz * t, d)
    return pmatmul(out, wo).reshape(bsz, t, d)


def _layernorm(h, eps=1e-5):
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.var(h, axis=-1, keepdims=True)
    return (h - mu) / jnp.sqrt(var + eps)


def _block_params(prefix: str, d: int, ff: int) -> list[ParamSpec]:
    s = 0.02
    return [
        ParamSpec(f"{prefix}_wq", (d, d), s),
        ParamSpec(f"{prefix}_wk", (d, d), s),
        ParamSpec(f"{prefix}_wv", (d, d), s),
        ParamSpec(f"{prefix}_wo", (d, d), s),
        ParamSpec(f"{prefix}_w1", (d, ff), s),
        ParamSpec(f"{prefix}_b1", (1, ff), 0.0),
        ParamSpec(f"{prefix}_w2", (ff, d), s),
        ParamSpec(f"{prefix}_b2", (1, d), 0.0),
    ]


def _apply_block(h, p, heads: int, causal: bool):
    """Pre-LN transformer block; p is the 8-tuple from `_block_params`."""
    wq, wk, wv, wo, w1, b1, w2, b2 = p
    h = h + _attention(_layernorm(h), wq, wk, wv, wo, heads, causal)
    bsz, t, d = h.shape
    flat = _layernorm(h).reshape(bsz * t, d)
    ff = jax.nn.relu(pmatmul(flat, w1) + b1)
    h = h + (pmatmul(ff, w2) + b2).reshape(bsz, t, d)
    return h


# --------------------------------------------------------------------------
# ViT analog (patch attention classifier)
# --------------------------------------------------------------------------

def make_vit(name: str, side: int, patch: int, d: int, heads: int, blocks: int,
             classes: int, batch: int, ff_mult: int = 2) -> ModelDef:
    assert side % patch == 0
    n_patches = (side // patch) ** 2
    patch_dim = patch * patch
    ff = ff_mult * d

    params: list[ParamSpec] = [
        ParamSpec("embed_w", (patch_dim, d), (1.0 / patch_dim) ** 0.5),
        ParamSpec("pos", (n_patches, d), 0.02),
    ]
    for i in range(blocks):
        params.extend(_block_params(f"blk{i}", d, ff))
    params.append(ParamSpec("head_w", (d, classes), (1.0 / d) ** 0.5))
    params.append(ParamSpec("head_b", (1, classes), 0.0))

    def forward(plist, x):
        bsz = x.shape[0]
        # [B, side²] → [B, np, patch_dim]  (patch grid row-major)
        img = x.reshape(bsz, side, side)
        g = side // patch
        patches = (
            img.reshape(bsz, g, patch, g, patch)
            .transpose(0, 1, 3, 2, 4)
            .reshape(bsz * n_patches, patch_dim)
        )
        h = pmatmul(patches, plist[0]).reshape(bsz, n_patches, d) + plist[1]
        idx = 2
        for _ in range(blocks):
            h = _apply_block(h, plist[idx:idx + 8], heads, causal=False)
            idx += 8
        pooled = jnp.mean(_layernorm(h), axis=1)
        return pmatmul(pooled, plist[idx]) + plist[idx + 1]

    def loss_fn(plist, x, y):
        return _ce_loss(forward(plist, x), y)

    return ModelDef(
        name=name,
        kind="classifier",
        params=params,
        loss_fn=loss_fn,
        eval_fn=forward,
        batch=batch,
        meta={"dim": side * side, "classes": classes},
    )


# --------------------------------------------------------------------------
# Decoder-only LM (LLaMA analog)
# --------------------------------------------------------------------------

def make_lm(name: str, vocab: int, d: int, heads: int, blocks: int, seq: int,
            batch: int, ff_mult: int = 2) -> ModelDef:
    ff = ff_mult * d
    params: list[ParamSpec] = [
        ParamSpec("embed", (vocab, d), 0.02),
        ParamSpec("pos", (seq, d), 0.02),
    ]
    for i in range(blocks):
        params.extend(_block_params(f"blk{i}", d, ff))
    params.append(ParamSpec("head", (d, vocab), (1.0 / d) ** 0.5))

    def nll(plist, x, y):
        bsz = x.shape[0]
        h = plist[0][x] + plist[1][None, :, :]
        idx = 2
        for _ in range(blocks):
            h = _apply_block(h, plist[idx:idx + 8], heads, causal=True)
            idx += 8
        flat = _layernorm(h).reshape(bsz * seq, d)
        logits = pmatmul(flat, plist[idx])
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, y.reshape(-1)[:, None], axis=-1)[:, 0]
        return jnp.mean(lse - picked)

    return ModelDef(
        name=name,
        kind="lm",
        params=params,
        loss_fn=nll,
        eval_fn=nll,
        batch=batch,
        meta={"vocab": vocab, "seq": seq, "d": d},
    )


# --------------------------------------------------------------------------
# Registry — the analog suite behind every table (DESIGN.md §3)
# --------------------------------------------------------------------------

def registry() -> dict[str, ModelDef]:
    models = [
        # Tab. 2/3 (CIFAR-100 analog, 32 classes)
        make_mlp("mlp_vgg_c32", dim=64, hidden=[128, 128, 96], classes=32, batch=64),
        make_resmlp("res_mlp_c32", dim=64, width=96, blocks=3, classes=32, batch=64),
        make_vit("swin_lite_c32", side=8, patch=2, d=48, heads=4, blocks=1,
                 classes=32, batch=64),
        make_vit("vit_lite_c32", side=8, patch=2, d=48, heads=4, blocks=2,
                 classes=32, batch=64),
        # Tab. 4 (Tiny-ImageNet analog, 64 classes)
        make_mlp("mlp_vgg_c64", dim=64, hidden=[128, 128, 96], classes=64, batch=64),
        make_resmlp("res_mlp_c64", dim=64, width=96, blocks=3, classes=64, batch=64),
        make_vit("swin_lite_c64", side=8, patch=2, d=48, heads=4, blocks=1,
                 classes=64, batch=64),
        make_vit("vit_lite_c64", side=8, patch=2, d=48, heads=4, blocks=2,
                 classes=64, batch=64),
        # Tab. 5 (ImageNet analog: bigger bodies, 64 classes)
        make_resmlp("res_big_c64", dim=64, width=192, blocks=4, classes=64, batch=64),
        make_vit("vit_big_c64", side=8, patch=2, d=96, heads=4, blocks=2,
                 classes=64, batch=64),
        # Tab. 6 (LLaMA/C4 analog, three sizes)
        make_lm("lm_s", vocab=64, d=32, heads=4, blocks=2, seq=32, batch=16),
        make_lm("lm_m", vocab=64, d=64, heads=4, blocks=3, seq=32, batch=16),
        make_lm("lm_l", vocab=64, d=128, heads=8, blocks=4, seq=32, batch=16),
    ]
    return {m.name: m for m in models}


def fwd_bwd_fn(model: ModelDef):
    """(params…, x, y) ↦ (loss, grads…) — the artifact the trainer runs."""
    n = len(model.params)

    def f(*args):
        plist = list(args[:n])
        x, y = args[n], args[n + 1]
        loss, grads = jax.value_and_grad(model.loss_fn)(plist, x, y)
        return (loss, *grads)

    return f


def eval_fn(model: ModelDef):
    """Classifier: (params…, x) ↦ logits. LM: (params…, x, y) ↦ nll."""
    n = len(model.params)

    if model.kind == "classifier":
        def f(*args):
            return (model.eval_fn(list(args[:n]), args[n]),)
        return f

    def f(*args):
        return (model.eval_fn(list(args[:n]), args[n], args[n + 1]),)

    return f

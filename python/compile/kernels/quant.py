"""L1 Pallas kernels: block-wise 4-bit quantize / dequantize.

TPU-shaped thinking (DESIGN.md §Hardware-Adaptation): each grid program
owns one B×B tile resident in VMEM; the absmax reduction is a VPU
tree-reduce; the per-tile scale lives beside the codes. ``interpret=True``
everywhere — the CPU PJRT client cannot execute Mosaic custom-calls, and
correctness (vs ``ref.py``) is the contract at this layer.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import linear2_levels


def _quantize_kernel(x_ref, levels_ref, codes_ref, scale_ref):
    """One B×B tile: absmax → normalize → nearest-level encode (Eq. 3)."""
    x = x_ref[...]
    levels = levels_ref[...]
    amax = jnp.max(jnp.abs(x))
    inv = jnp.where(amax > 0, 1.0 / amax, 0.0)
    xn = x * inv
    d = jnp.abs(xn[..., None] - levels)
    codes_ref[...] = jnp.argmin(d, axis=-1).astype(jnp.int32)
    scale_ref[...] = jnp.full((1, 1), amax, dtype=jnp.float32)


def _dequantize_kernel(codes_ref, scale_ref, levels_ref, x_ref):
    """One B×B tile: codebook lookup × tile scale."""
    codes = codes_ref[...]
    levels = levels_ref[...]
    scale = scale_ref[0, 0]
    x_ref[...] = levels[codes] * scale


def _padded(x: jnp.ndarray, block: int) -> jnp.ndarray:
    m, n = x.shape
    pm, pn = (-m) % block, (-n) % block
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


@partial(jax.jit, static_argnames=("block", "bits"))
def blockwise_quantize(x: jnp.ndarray, block: int = 64, bits: int = 4):
    """Block-wise quantization via a Pallas grid over tiles.

    Returns ``(codes[int32, padded m×n], scales[f32, bm×bn])``.
    """
    levels = jnp.asarray(linear2_levels(bits))
    xp = _padded(x, block)
    mp, np_ = xp.shape
    grid = (mp // block, np_ // block)
    nlev = levels.shape[0]
    codes, scales = pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j: (i, j)),
            pl.BlockSpec((nlev,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block, block), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, np_), jnp.int32),
            jax.ShapeDtypeStruct(grid, jnp.float32),
        ],
        interpret=True,
    )(xp, levels)
    return codes, scales


@partial(jax.jit, static_argnames=("block", "bits"))
def blockwise_dequantize(codes: jnp.ndarray, scales: jnp.ndarray, block: int = 64,
                         bits: int = 4) -> jnp.ndarray:
    """Dequantize (padded shape; caller crops)."""
    levels = jnp.asarray(linear2_levels(bits))
    mp, np_ = codes.shape
    grid = (mp // block, np_ // block)
    nlev = levels.shape[0]
    return pl.pallas_call(
        _dequantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            pl.BlockSpec((nlev,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(codes, scales, levels)


@partial(jax.jit, static_argnames=("block", "bits"))
def quantize_roundtrip(x: jnp.ndarray, block: int = 64, bits: int = 4) -> jnp.ndarray:
    """D(Q(x)), cropped to x's shape — the op the rust runtime AOT-loads to
    validate kernel numerics end-to-end through PJRT."""
    codes, scales = blockwise_quantize(x, block=block, bits=bits)
    back = blockwise_dequantize(codes, scales, block=block, bits=bits)
    return back[: x.shape[0], : x.shape[1]]

"""Pure-jnp oracles for every Pallas kernel (the L1 correctness contract).

Each function here is the mathematical definition the kernels in
``quant.py`` / ``precond.py`` / ``gram.py`` must reproduce bit-for-bit
(modulo f32 accumulation order). pytest + hypothesis sweep shapes, block
sizes and value distributions against these.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def linear2_levels(bits: int = 4) -> np.ndarray:
    """The paper's Eq. (4) linear-square codebook, strictly increasing."""
    n = 1 << bits
    half = n // 2 - 1
    js = np.arange(n, dtype=np.float32)
    u = -1.0 + 2.0 * js / (n - 1)
    vals = np.where(js < half, -(u * u), np.where(js == half, 0.0, u * u))
    return vals.astype(np.float32)


def encode_nearest(xn: jnp.ndarray, levels: jnp.ndarray) -> jnp.ndarray:
    """argmin_j |xn - M(j)| (paper Eq. (3)), ties toward the lower index."""
    d = jnp.abs(xn[..., None] - levels)
    return jnp.argmin(d, axis=-1).astype(jnp.int32)


def _pad_to_blocks(x: jnp.ndarray, block: int) -> jnp.ndarray:
    m, n = x.shape
    pm = (-m) % block
    pn = (-n) % block
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


def blockwise_quantize_ref(x: jnp.ndarray, block: int, levels: jnp.ndarray):
    """Block-wise absmax quantization (paper Sec. 3.2).

    Returns ``(codes, scales)`` where ``codes`` has x's (padded) shape and
    ``scales`` is ``[ceil(m/B), ceil(n/B)]``.
    """
    xp = _pad_to_blocks(x, block)
    mp, np_ = xp.shape
    bm, bn = mp // block, np_ // block
    tiles = xp.reshape(bm, block, bn, block).transpose(0, 2, 1, 3)
    scales = jnp.max(jnp.abs(tiles), axis=(2, 3))
    inv = jnp.where(scales > 0, 1.0 / scales, 0.0)
    xn = tiles * inv[:, :, None, None]
    codes = encode_nearest(xn, levels)
    codes = codes.transpose(0, 2, 1, 3).reshape(mp, np_)
    return codes, scales


def blockwise_dequantize_ref(codes: jnp.ndarray, scales: jnp.ndarray, block: int,
                             levels: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`blockwise_quantize_ref` (padded shape)."""
    mp, np_ = codes.shape
    bm, bn = mp // block, np_ // block
    vals = levels[codes]
    tiles = vals.reshape(bm, block, bn, block).transpose(0, 2, 1, 3)
    tiles = tiles * scales[:, :, None, None]
    return tiles.transpose(0, 2, 1, 3).reshape(mp, np_)


def roundtrip_ref(x: jnp.ndarray, block: int, levels: jnp.ndarray) -> jnp.ndarray:
    """D(Q(x)) cropped back to x's shape."""
    codes, scales = blockwise_quantize_ref(x, block, levels)
    back = blockwise_dequantize_ref(codes, scales, block, levels)
    return back[: x.shape[0], : x.shape[1]]


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return a @ b


def precond_apply_ref(lhat: jnp.ndarray, g: jnp.ndarray, rhat: jnp.ndarray) -> jnp.ndarray:
    """Algorithm 1 line 15: Ĝ = L̂ · G · R̂."""
    return lhat @ g @ rhat


def gram_ema_ref(prev: jnp.ndarray, g: jnp.ndarray, beta: float, left: bool) -> jnp.ndarray:
    """Eq. (2)/(7): β·prev + (1−β)·(G·Gᵀ or Gᵀ·G)."""
    gram = g @ g.T if left else g.T @ g
    return beta * prev + (1.0 - beta) * gram

"""L1 Pallas kernels: tiled matmul + the preconditioner application
`Ĝ = L̂·G·R̂` (Algorithm 1 line 15).

TPU mapping (DESIGN.md §Hardware-Adaptation): MXU-friendly (tile_m×K)·(K×
tile_n) tiles; the (L̂·G) intermediate stays in VMEM between the two
chained products. A custom VJP routes the backward pass through the same
kernel (three matmuls), so the L1 kernel lowers into both the fwd and bwd
HLO of every L2 model graph.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] @ b_ref[...]


def _tile(n: int, cap: int = 128) -> int:
    """Largest tile ≤ cap dividing n (falls back to n itself)."""
    for t in (cap, 64, 32, 16, 8, 4, 2):
        if n % t == 0 and t <= n:
            return t
    return n


def pallas_matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """`a @ b` via a Pallas grid of (tile_m, K)×(K, tile_n) programs."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dim mismatch {a.shape} @ {b.shape}"
    tm, tn = _tile(m), _tile(n)
    grid = (m // tm, n // tn)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


@jax.custom_vjp
def pmatmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Differentiable Pallas matmul: models use this for dense layers so the
    L1 kernel is embedded in the lowered fwd+bwd HLO."""
    return pallas_matmul(a, b)


def _pmatmul_fwd(a, b):
    return pallas_matmul(a, b), (a, b)


def _pmatmul_bwd(res, ct):
    a, b = res
    # dA = ct @ Bᵀ, dB = Aᵀ @ ct — same kernel, transposed operands.
    return pallas_matmul(ct, b.T), pallas_matmul(a.T, ct)


pmatmul.defvjp(_pmatmul_fwd, _pmatmul_bwd)


@jax.jit
def precond_apply(lhat: jnp.ndarray, g: jnp.ndarray, rhat: jnp.ndarray) -> jnp.ndarray:
    """Ĝ = L̂·G·R̂ as two chained Pallas matmuls."""
    return pallas_matmul(pallas_matmul(lhat, g), rhat)


@partial(jax.jit, static_argnames=("left",))
def gram_ema(prev: jnp.ndarray, g: jnp.ndarray, beta: jnp.ndarray, left: bool = True):
    """Eq. (2)/(7) EMA Gram update with the product on the Pallas kernel.

    `beta` is a traced scalar so one artifact serves every β.
    """
    gram = pallas_matmul(g, g.T) if left else pallas_matmul(g.T, g)
    return beta * prev + (1.0 - beta) * gram

"""AOT driver: lower every L2 graph (and standalone L1 kernel graphs) to
HLO **text** + write ``artifacts/manifest.json``.

HLO text (not serialized protos) is the interchange format: jax ≥ 0.5 emits
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md and aot_recipe).

Run once via ``make artifacts``; python is never on the request path.

Usage: python -m compile.aot --out-dir ../artifacts [--only name,…]
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_mod
from .kernels import precond, quant


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def _spec_json(s) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def lower_model_artifacts(m: model_mod.ModelDef, out_dir: str, manifest: dict) -> None:
    pspecs = m.param_specs()
    in_specs = m.input_specs()

    # fwd_bwd: (*params, x, y) -> (loss, *grads)
    fb = model_mod.fwd_bwd_fn(m)
    lowered = jax.jit(fb).lower(*pspecs, *in_specs)
    fb_file = f"{m.name}.fwd_bwd.hlo.txt"
    with open(os.path.join(out_dir, fb_file), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest["artifacts"][f"{m.name}.fwd_bwd"] = {
        "file": fb_file,
        "inputs": [_spec_json(s) for s in (*pspecs, *in_specs)],
        "outputs": 1 + len(m.params),
    }

    # eval: classifier (*params, x) -> (logits,) ; lm (*params, x, y) -> (nll,)
    ev = model_mod.eval_fn(m)
    ev_inputs = (*pspecs, in_specs[0]) if m.kind == "classifier" else (*pspecs, *in_specs)
    lowered = jax.jit(ev).lower(*ev_inputs)
    ev_file = f"{m.name}.eval.hlo.txt"
    with open(os.path.join(out_dir, ev_file), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest["artifacts"][f"{m.name}.eval"] = {
        "file": ev_file,
        "inputs": [_spec_json(s) for s in ev_inputs],
        "outputs": 1,
    }

    manifest["models"][m.name] = {
        "kind": m.kind,
        "batch": m.batch,
        "meta": m.meta,
        "params": [
            {"name": p.name, "rows": p.shape[0], "cols": p.shape[1], "std": p.std}
            for p in m.params
        ],
    }


def lower_kernel_artifacts(out_dir: str, manifest: dict) -> None:
    """Standalone L1 kernel graphs — exercised directly by the rust runtime
    tests/benches to prove Pallas → HLO → PJRT composition."""
    f32 = jnp.float32

    entries = {
        "kernel.quant_roundtrip": (
            lambda x: (quant.quantize_roundtrip(x, block=64),),
            (jax.ShapeDtypeStruct((128, 128), f32),),
        ),
        "kernel.precond_apply": (
            lambda l, g, r: (precond.precond_apply(l, g, r),),
            (
                jax.ShapeDtypeStruct((64, 64), f32),
                jax.ShapeDtypeStruct((64, 48), f32),
                jax.ShapeDtypeStruct((48, 48), f32),
            ),
        ),
        "kernel.gram_ema_left": (
            lambda prev, g, beta: (precond.gram_ema(prev, g, beta, left=True),),
            (
                jax.ShapeDtypeStruct((64, 64), f32),
                jax.ShapeDtypeStruct((64, 48), f32),
                jax.ShapeDtypeStruct((), f32),
            ),
        ),
    }
    for name, (fn, specs) in entries.items():
        lowered = jax.jit(fn).lower(*specs)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(to_hlo_text(lowered))
        manifest["artifacts"][name] = {
            "file": fname,
            "inputs": [_spec_json(s) for s in specs],
            "outputs": 1,
        }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default="", help="comma-separated model names")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest: dict = {"artifacts": {}, "models": {}}

    reg = model_mod.registry()
    only = {s for s in args.only.split(",") if s}
    names = [n for n in reg if not only or n in only]

    lower_kernel_artifacts(args.out_dir, manifest)
    print(f"[aot] kernel artifacts done", flush=True)
    for i, name in enumerate(names):
        lower_model_artifacts(reg[name], args.out_dir, manifest)
        print(f"[aot] {i + 1}/{len(names)} {name}", flush=True)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] wrote {len(manifest['artifacts'])} artifacts to {args.out_dir}")


if __name__ == "__main__":
    sys.exit(main())

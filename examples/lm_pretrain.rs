//! End-to-end driver (DESIGN.md §End-to-end validation): pre-train a
//! decoder-only transformer LM on a synthetic Markov corpus for several
//! hundred steps with 4-bit Shampoo (CQ+EF), comparing against the AdamW
//! baseline, and log both loss curves — the Tab. 6 workload at example scale.
//!
//! All layers compose here: the L2 JAX graph (with L1 Pallas matmuls inside
//! its fwd+bwd HLO) is executed through PJRT from the rust trainer, and the
//! optimizer states live in rust-native 4-bit quantized storage.
//!
//! ```bash
//! cargo run --release --example lm_pretrain            # full (~minutes)
//! QUARTZ_LM_STEPS=60 cargo run --release --example lm_pretrain
//! ```

use quartz::data::tokens::{CorpusSpec, TokenCorpus};
use quartz::optim::{BaseOptimizer, LrSchedule, OptimizerKind};
use quartz::runtime::Runtime;
use quartz::shampoo::ShampooConfig;
use quartz::train::{registry, train_lm, TrainConfig};
use quartz::util::csv::CsvWriter;
use quartz::util::fmt_bytes;
use std::path::Path;

fn main() -> quartz::util::error::Result<()> {
    let steps: u64 = std::env::var("QUARTZ_LM_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);

    let rt = Runtime::open_default()?;
    let model = rt.manifest.models["lm_m"].clone();
    println!(
        "pre-training {} ({} weights, vocab {}, seq {}) for {steps} steps",
        model.name,
        model.n_weights(),
        model.meta_usize("vocab").unwrap(),
        model.meta_usize("seq").unwrap()
    );

    let corpus = TokenCorpus::generate(&CorpusSpec {
        length: 200_000,
        seed: 99,
        ..Default::default()
    });
    println!(
        "corpus: {} tokens, unigram entropy {:.3} nats",
        corpus.len(),
        corpus.unigram_entropy()
    );

    let cfg = TrainConfig {
        steps,
        schedule: LrSchedule::CosineWarmup { warmup: 20, total: steps, min_frac: 0.1 },
        eval_every: (steps / 8).max(1),
        log_every: (steps / 40).max(1),
        seed: 99,
        ..Default::default()
    };

    let adamw = || {
        let mut h = quartz::coordinator::spec::OptimizerSpec::paper_hyper(OptimizerKind::AdamW);
        h.lr = 3e-3;
        h.weight_decay = 0.0;
        BaseOptimizer::new(OptimizerKind::AdamW, h)
    };

    // Both rows by registry key: AdamW alone, and AdamW + 4-bit Shampoo
    // (CQ+EF) — swap "cq-ef" for any `quartz codecs` key to compare others.
    let scfg = ShampooConfig { t1: 10, t2: 50, max_order: 96, ..Default::default() };
    let stack = |key| {
        registry::build(key, adamw(), &scfg, &model.shapes()).expect("builtin stack key")
    };
    let base_run = train_lm(&rt, &model, &corpus, stack("none"), &cfg)?;
    let ours_run = train_lm(&rt, &model, &corpus, stack("cq-ef"), &cfg)?;

    // Log curves.
    std::fs::create_dir_all("runs")?;
    let mut w = CsvWriter::create(
        Path::new("runs/lm_pretrain.csv"),
        &["optimizer", "series", "step", "value"],
    )?;
    for (label, run) in [("adamw", &base_run), ("adamw+shampoo-cqef", &ours_run)] {
        for (s, l) in &run.loss_curve {
            w.row(&[label.into(), "train_nll".into(), format!("{s}"), format!("{l}")])?;
        }
        for (s, p) in &run.eval_curve {
            w.row(&[label.into(), "ppl".into(), format!("{s}"), format!("{p}")])?;
        }
    }
    w.flush()?;

    println!("\n{:<28} {:>10} {:>14} {:>10}", "optimizer", "PPL", "opt-state", "wall (s)");
    for run in [&base_run, &ours_run] {
        println!(
            "{:<28} {:>10.3} {:>14} {:>10.1}",
            run.optimizer,
            run.final_metric,
            fmt_bytes(run.state_bytes as u64),
            run.wall_secs
        );
    }
    println!("\nloss curves written to runs/lm_pretrain.csv");
    quartz::ensure!(
        ours_run.final_metric < model.meta_usize("vocab").unwrap() as f64,
        "PPL must beat uniform"
    );
    Ok(())
}

//! Image-classification scenario: the ViT analog on synthetic pattern
//! images (frequency templates + noise), comparing all four Shampoo
//! variants side-by-side — a compact version of the paper's Tab. 3 row.
//!
//! ```bash
//! cargo run --release --example image_classify
//! ```

use quartz::data::images::{ImageDataset, ImageSpec};
use quartz::optim::{BaseOptimizer, LrSchedule};
use quartz::report::table::{mb, pct, Table};
use quartz::runtime::Runtime;
use quartz::shampoo::ShampooConfig;
use quartz::train::{registry, train_classifier, ClassifierData, TrainConfig};

fn main() -> quartz::util::error::Result<()> {
    let rt = Runtime::open_default()?;
    // vit_lite_c32 consumes flattened 8×8 images (dim 64).
    let model = rt.manifest.models["vit_lite_c32"].clone();

    let (tr, te) = ImageDataset::generate(&ImageSpec {
        side: 8,
        classes: 32,
        train: 4096,
        test: 1024,
        noise: 0.5,
        seed: 21,
    });
    let data = ClassifierData::from((&tr, &te));
    println!("ViT analog on {}×{} synthetic pattern images, {} classes", 8, 8, 32);

    let steps = 400;
    let cfg = TrainConfig {
        steps,
        schedule: LrSchedule::CosineWarmup { warmup: 20, total: steps, min_frac: 0.05 },
        eval_every: 0,
        log_every: 50,
        seed: 21,
        ..Default::default()
    };

    let adamw = || BaseOptimizer::adamw(1e-3, 0.9, 0.999, 1e-8, 5e-2);
    let mut table = Table::new(
        "ViT analog — optimizer comparison (synthetic images)",
        &["Optimizer", "Accuracy (%)", "Opt-State (MB)", "Wall (s)"],
    );

    // Every variant by registry key: the base alone, the paper's four
    // Shampoo representations, and the 8-bit codec — one loop, no
    // per-variant construction code.
    let scfg = ShampooConfig { t1: 10, t2: 50, max_order: 96, ..Default::default() };
    for key in ["none", "32bit", "vq", "cq", "cq-ef", "bw8"] {
        let opt = registry::build(key, adamw(), &scfg, &model.shapes())
            .expect("builtin stack key");
        let run = train_classifier(&rt, &model, &data, opt, &cfg)?;
        table.row(vec![
            run.optimizer.clone(),
            pct(run.final_metric),
            mb(run.state_bytes),
            format!("{:.1}", run.wall_secs),
        ]);
    }

    table.print();
    Ok(())
}

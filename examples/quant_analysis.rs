//! Quantization analysis (paper Sec. 4.2, Tabs. 1 & 9): how much do VQ and
//! CQ perturb the inverse-4th-root of ill-conditioned preconditioners?
//! Pure library usage — no artifacts required.
//!
//! ```bash
//! cargo run --release --example quant_analysis
//! ```

use quartz::analysis::{cq_roundtrip, nre_ae, synthetic_pd, vq_roundtrip};
use quartz::linalg::{eig_sym, Matrix};
use quartz::quant::{BlockQuantizer, QuantConfig};
use quartz::report::table::Table;
use quartz::util::rng::Rng;

fn main() -> quartz::util::error::Result<()> {
    let q = BlockQuantizer::new(QuantConfig { min_quant_elems: 0, ..Default::default() });

    // 1. The paper's toy 2×2 (App. C.1): VQ breaks PD, CQ does not.
    let q2 =
        BlockQuantizer::new(QuantConfig { block: 2, min_quant_elems: 0, ..Default::default() });
    let l = Matrix::from_rows(&[&[10.0, 3.0], &[3.0, 1.0]]);
    let vq = vq_roundtrip(&l, &q2);
    let cq = cq_roundtrip(&l, 1e-6, &q2);
    let eig = |m: &Matrix| {
        let (v, _) = eig_sym(m, 1e-12, 100);
        (v[1], v[0])
    };
    println!("Toy 2×2 [[10,3],[3,1]] — eigenvalues (λmax, λmin):");
    println!("  original: {:?}", eig(&l));
    println!("  VQ      : {:?}   <- PD broken (negative λmin)", eig(&vq));
    println!("  CQ      : {:?}   <- PSD by construction\n", eig(&cq));

    // 2. NRE/AE sweep over condition numbers: CQ's advantage grows with
    //    ill-conditioning (the paper's synthetic setting at κ = 1e6).
    let mut t = Table::new(
        "NRE / AE of inverse-4th-roots vs condition number (mean of 10 matrices, n = 64)",
        &["κ(A)", "VQ NRE", "VQ AE (deg)", "CQ NRE", "CQ AE (deg)", "CQ/VQ NRE"],
    );
    let mut rng = Rng::new(5);
    for kappa_pow in [1, 2, 3, 4, 6] {
        let hi = 10f32.powi(kappa_pow);
        let (mut vq_nre, mut vq_ae, mut cq_nre, mut cq_ae) = (0.0, 0.0, 0.0, 0.0);
        let n_mats = 10;
        for _ in 0..n_mats {
            let a = synthetic_pd(64, 1.0 / hi.sqrt(), hi.sqrt(), &mut rng);
            let (n1, a1) = nre_ae(&a, &vq_roundtrip(&a, &q));
            let (n2, a2) = nre_ae(&a, &cq_roundtrip(&a, 1e-6, &q));
            vq_nre += n1 / n_mats as f64;
            vq_ae += a1 / n_mats as f64;
            cq_nre += n2 / n_mats as f64;
            cq_ae += a2 / n_mats as f64;
        }
        t.row(vec![
            format!("1e{kappa_pow}"),
            format!("{vq_nre:.4}"),
            format!("{vq_ae:.3}"),
            format!("{cq_nre:.4}"),
            format!("{cq_ae:.3}"),
            format!("{:.3}", cq_nre / vq_nre),
        ]);
    }
    t.print();

    // 3. The codec API: the same perturbation measurement through registered
    //    `PrecondCodec`s — any key from `quartz codecs` (including codecs
    //    registered by downstream crates) drops into this loop.
    let ctx = quartz::quant::CodecCtx::new(1e-6, 0.95, std::sync::Arc::new(q.clone()));
    let mut tc = Table::new(
        "NRE / AE of inverse-4th-roots by preconditioner codec (κ = 1e4, n = 64)",
        &["codec", "NRE", "AE (deg)"],
    );
    let mut rng_c = Rng::new(7);
    for key in ["f32", "vq4", "bw8", "cq4", "cq4-ef"] {
        let b = quartz::quant::codec::lookup(key).expect("builtin codec");
        let (mut nre, mut ae) = (0.0, 0.0);
        let n_mats = 5;
        let warm_stores = 8;
        for _ in 0..n_mats {
            let a = synthetic_pd(64, 1e-2, 1e2, &mut rng_c);
            let mut codec = (b.side)(&ctx);
            // Repeated stores of the same matrix, as the T1 refresh loop
            // does — this is what lets cq4-ef's error feedback accumulate
            // and separate from plain cq4 (a single store is EF-neutral).
            for _ in 0..warm_stores {
                codec.store(&a);
            }
            let (n1, a1) = nre_ae(&a, &codec.load());
            nre += n1 / n_mats as f64;
            ae += a1 / n_mats as f64;
        }
        tc.row(vec![key.to_string(), format!("{nre:.4}"), format!("{ae:.3}")]);
    }
    tc.print();

    // 4. Error-feedback effect: time-averaged reconstruction error of a
    //    repeatedly quantized Cholesky factor with and without EF.
    let ef = quartz::quant::ErrorFeedback::new(0.95);
    let mut rng = Rng::new(9);
    let n = 32;
    let c = Matrix::from_fn(n, n, |i, j| {
        if i > j {
            rng.normal_f32(1.0)
        } else if i == j {
            3.0
        } else {
            0.0
        }
    });
    let steps = 200;
    let mut e = Matrix::zeros(n, n);
    let mut avg_ef = Matrix::zeros(n, n);
    let mut avg_plain = Matrix::zeros(n, n);
    for _ in 0..steps {
        let comp = ef.compensate(&c, &e);
        let back = q.roundtrip(&comp);
        e = ef.update(&c, &e, &back);
        avg_ef.axpy(1.0 / steps as f32, &back);
        avg_plain.axpy(1.0 / steps as f32, &q.roundtrip(&c));
    }
    let err = |avg: &Matrix| {
        let mut s = 0.0f64;
        for i in 0..n {
            for j in 0..i {
                s += ((avg[(i, j)] - c[(i, j)]) as f64).powi(2);
            }
        }
        s.sqrt()
    };
    println!("\nError feedback, time-averaged factor error over {steps} quantizations:");
    println!("  without EF: {:.5}", err(&avg_plain));
    println!("  with EF   : {:.5}  (Eq. 10-11 compensation)", err(&avg_ef));
    Ok(())
}

//! Quickstart: train a small classifier with 4-bit Shampoo (CQ+EF) through
//! the full three-layer stack (rust coordinator → PJRT-compiled JAX fwd/bwd
//! with embedded Pallas kernels → rust-native quantized optimizer).
//!
//! ```bash
//! make artifacts            # once
//! cargo run --release --example quickstart
//! ```

use quartz::data::synthetic::{ClusterDataset, ClusterSpec};
use quartz::optim::{BaseOptimizer, LrSchedule};
use quartz::runtime::Runtime;
use quartz::shampoo::ShampooConfig;
use quartz::train::{registry, train_classifier, ClassifierData, TrainConfig};
use quartz::util::fmt_bytes;

fn main() -> quartz::util::error::Result<()> {
    // 1. Open the AOT artifact bundle (python ran once at build time).
    let rt = Runtime::open_default()?;
    let model = rt.manifest.models["res_mlp_c32"].clone();
    let (name, n_params, n_weights) = (&model.name, model.params.len(), model.n_weights());
    println!("model {name} — {n_params} params, {n_weights} weights");

    // 2. Synthetic 32-class workload (CIFAR-100 analog).
    let (tr, te) = ClusterDataset::generate(&ClusterSpec {
        classes: 32,
        dim: 64,
        seed: 7,
        ..Default::default()
    });
    let data = ClassifierData::from((&tr, &te));

    // 3. 4-bit Shampoo (compensated Cholesky quantization, Algorithm 1)
    //    wrapping SGDM — the paper's headline configuration, constructed by
    //    registry key: any variant in `quartz codecs` works here.
    let cfg = ShampooConfig { t1: 10, t2: 50, max_order: 96, ..Default::default() };
    let opt = registry::build("cq-ef", BaseOptimizer::sgdm(0.05, 0.9, 5e-4), &cfg, &model.shapes())
        .expect("cq-ef is a builtin stack key");

    // 4. Train.
    let steps = 400;
    let train_cfg = TrainConfig {
        steps,
        schedule: LrSchedule::CosineWarmup { warmup: 20, total: steps, min_frac: 0.05 },
        eval_every: 100,
        log_every: 25,
        seed: 7,
        ..Default::default()
    };
    let m = train_classifier(&rt, &model, &data, opt, &train_cfg)?;

    println!("\noptimizer: {}", m.optimizer);
    for (step, loss) in &m.loss_curve {
        println!("  step {step:>4}  loss {loss:.4}");
    }
    for (step, acc) in &m.eval_curve {
        println!("  step {step:>4}  test-acc {:.2}%", acc * 100.0);
    }
    println!("\nfinal accuracy : {:.2}%", m.final_metric * 100.0);
    println!("optimizer state: {}", fmt_bytes(m.state_bytes as u64));
    println!("wall time      : {:.1}s (optimizer {:.1}s)", m.wall_secs, m.opt_secs);
    Ok(())
}
